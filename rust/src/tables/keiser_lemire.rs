//! Nibble-classification tables for the Keiser–Lemire UTF-8 validator
//! (Keiser & Lemire, "Validating UTF-8 in less than one instruction per
//! byte", SPE 2021 — reference [3] of the paper; §4 applies it for the
//! validating transcoder).
//!
//! The validator classifies every adjacent byte pair through three
//! 16-entry tables indexed by (high nibble of previous byte, low nibble
//! of previous byte, high nibble of current byte). The AND of the three
//! looked-up classes is non-zero exactly where a *special-case* error
//! could exist; combined with a saturating-subtraction check for 3/4-byte
//! continuation runs, the OR-reduction over the input is zero iff the
//! input is valid UTF-8.

/// Error-class bit: lead byte followed by another lead/ASCII. (All
/// class names follow the original publication.)
pub const TOO_SHORT: u8 = 1 << 0;
/// ASCII followed by a continuation byte.
pub const TOO_LONG: u8 = 1 << 1;
/// E0 followed by 80..9F (overlong 3-byte encoding).
pub const OVERLONG_3: u8 = 1 << 2;
/// F4 followed by 90..BF etc. (> U+10FFFF).
pub const TOO_LARGE: u8 = 1 << 3;
/// ED followed by A0..BF (encoded surrogate).
pub const SURROGATE: u8 = 1 << 4;
/// C0/C1 lead: value < 0x80 in 2 bytes.
pub const OVERLONG_2: u8 = 1 << 5;
/// F5..FF lead or F4 9x: >= 0x140000.
pub const TOO_LARGE_1000: u8 = 1 << 6;
/// F0 followed by 80..8F (shares the bit with [`TOO_LARGE_1000`]).
pub const OVERLONG_4: u8 = 1 << 6;
/// Two continuation bytes in a row (resolved by the carry check).
pub const TWO_CONTS: u8 = 1 << 7;

/// Classes that must propagate through the second table unconditionally.
pub const CARRY: u8 = TOO_SHORT | TOO_LONG | TWO_CONTS;

/// Classification by the high nibble of the previous byte.
pub const BYTE_1_HIGH: [u8; 16] = [
    // 0x0_-0x7_: ASCII leads — only TOO_LONG is possible.
    TOO_LONG, TOO_LONG, TOO_LONG, TOO_LONG, TOO_LONG, TOO_LONG, TOO_LONG, TOO_LONG,
    // 0x8_-0xB_: continuation bytes.
    TWO_CONTS, TWO_CONTS, TWO_CONTS, TWO_CONTS,
    // 0xC_: 2-byte lead (C0/C1 overlong possible).
    TOO_SHORT | OVERLONG_2,
    // 0xD_: 2-byte lead.
    TOO_SHORT,
    // 0xE_: 3-byte lead (E0 overlong, ED surrogate possible).
    TOO_SHORT | OVERLONG_3 | SURROGATE,
    // 0xF_: 4-byte lead (F0 overlong, F4+/F5.. too large possible).
    TOO_SHORT | TOO_LARGE | TOO_LARGE_1000 | OVERLONG_4,
];

/// Classification by the low nibble of the previous byte.
pub const BYTE_1_LOW: [u8; 16] = [
    CARRY | OVERLONG_3 | OVERLONG_2 | OVERLONG_4, // 0
    CARRY | OVERLONG_2,                           // 1
    CARRY,                                        // 2
    CARRY,                                        // 3
    CARRY | TOO_LARGE,                            // 4
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // 5
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // 6
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // 7
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // 8
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // 9
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // A
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // B
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // C
    CARRY | TOO_LARGE | TOO_LARGE_1000 | SURROGATE, // D
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // E
    CARRY | TOO_LARGE | TOO_LARGE_1000,           // F
];

/// Classification by the high nibble of the current byte.
pub const BYTE_2_HIGH: [u8; 16] = [
    // 0x0_-0x7_: ASCII — an error iff the previous byte was a lead.
    TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT,
    // 0x8_: first half of continuation range.
    TOO_LONG | OVERLONG_2 | TWO_CONTS | OVERLONG_3 | TOO_LARGE_1000 | OVERLONG_4,
    // 0x9_: second quarter.
    TOO_LONG | OVERLONG_2 | TWO_CONTS | OVERLONG_3 | TOO_LARGE,
    // 0xA_, 0xB_: upper half (surrogates live here after ED).
    TOO_LONG | OVERLONG_2 | TWO_CONTS | SURROGATE | TOO_LARGE,
    TOO_LONG | OVERLONG_2 | TWO_CONTS | SURROGATE | TOO_LARGE,
    // 0xC_-0xF_: lead bytes — an error iff the previous byte was a lead.
    TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT,
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar reference: classify the pair (prev, cur) through the three
    /// tables, exactly as the vectorized code does.
    fn special_cases(prev: u8, cur: u8) -> u8 {
        BYTE_1_HIGH[(prev >> 4) as usize]
            & BYTE_1_LOW[(prev & 0x0F) as usize]
            & BYTE_2_HIGH[(cur >> 4) as usize]
    }

    #[test]
    fn ascii_pairs_are_clean() {
        for prev in 0..0x80u8 {
            for cur in [0u8, 0x41, 0x7F] {
                assert_eq!(special_cases(prev, cur), 0, "{prev:02x} {cur:02x}");
            }
        }
    }

    #[test]
    fn ascii_then_continuation_is_too_long() {
        assert_eq!(special_cases(0x41, 0x80) & TOO_LONG, TOO_LONG);
        assert_eq!(special_cases(0x7F, 0xBF) & TOO_LONG, TOO_LONG);
    }

    #[test]
    fn lead_then_ascii_is_too_short() {
        assert_eq!(special_cases(0xC2, 0x41) & TOO_SHORT, TOO_SHORT);
        assert_eq!(special_cases(0xE1, 0x20) & TOO_SHORT, TOO_SHORT);
        assert_eq!(special_cases(0xF1, 0x7F) & TOO_SHORT, TOO_SHORT);
        // lead then lead
        assert_eq!(special_cases(0xC2, 0xC2) & TOO_SHORT, TOO_SHORT);
    }

    #[test]
    fn valid_two_byte_is_clean() {
        // C2..DF followed by 80..BF is valid.
        for prev in 0xC2..=0xDFu8 {
            for cur in [0x80u8, 0x9F, 0xA0, 0xBF] {
                assert_eq!(special_cases(prev, cur), 0, "{prev:02x} {cur:02x}");
            }
        }
    }

    #[test]
    fn overlong_two_byte_flagged() {
        for cur in [0x80u8, 0xBF] {
            assert_eq!(special_cases(0xC0, cur) & OVERLONG_2, OVERLONG_2);
            assert_eq!(special_cases(0xC1, cur) & OVERLONG_2, OVERLONG_2);
        }
    }

    #[test]
    fn overlong_three_byte_flagged() {
        // E0 80..9F is overlong; E0 A0..BF is fine.
        assert_ne!(special_cases(0xE0, 0x80) & OVERLONG_3, 0);
        assert_ne!(special_cases(0xE0, 0x9F) & OVERLONG_3, 0);
        assert_eq!(special_cases(0xE0, 0xA0), 0);
        assert_eq!(special_cases(0xE0, 0xBF), 0);
    }

    #[test]
    fn surrogates_flagged() {
        // ED A0..BF encodes U+D800..DFFF.
        assert_ne!(special_cases(0xED, 0xA0) & SURROGATE, 0);
        assert_ne!(special_cases(0xED, 0xBF) & SURROGATE, 0);
        assert_eq!(special_cases(0xED, 0x80), 0);
        assert_eq!(special_cases(0xED, 0x9F), 0);
    }

    #[test]
    fn overlong_four_byte_flagged() {
        // F0 80..8F is overlong; F0 90..BF is fine.
        assert_ne!(special_cases(0xF0, 0x80), 0);
        assert_ne!(special_cases(0xF0, 0x8F), 0);
        assert_eq!(special_cases(0xF0, 0x90), 0);
        assert_eq!(special_cases(0xF0, 0xBF), 0);
    }

    #[test]
    fn too_large_flagged() {
        // F4 90..BF is > U+10FFFF; F4 80..8F is the last valid plane.
        assert_ne!(special_cases(0xF4, 0x90), 0);
        assert_eq!(special_cases(0xF4, 0x80), 0);
        assert_eq!(special_cases(0xF4, 0x8F), 0);
        // F5..FF always invalid with continuation
        for prev in [0xF5u8, 0xF8, 0xFF] {
            assert_ne!(special_cases(prev, 0x80), 0, "{prev:02x}");
        }
    }

    #[test]
    fn two_continuations_flagged_via_carry() {
        // A continuation followed by a continuation carries TWO_CONTS;
        // this is cancelled by the must-be-2/3-continuation check at the
        // vector level, so here we just confirm the bit fires.
        assert_ne!(special_cases(0x80, 0x80) & TWO_CONTS, 0);
        assert_ne!(special_cases(0xBF, 0xBF) & TWO_CONTS, 0);
        // ...and that continuation->ascii carries nothing.
        assert_eq!(special_cases(0x80, 0x41), 0);
    }
}
