//! Tables for the UTF-8 → UTF-16 transcoder (§4, Algorithm 2).
//!
//! The transcoder consumes 12-byte windows. From the low 12 bits of the
//! end-of-character bitset (bit `i` set ⟺ byte `i` ends a character) the
//! **main table** yields how many bytes the window consumes and which
//! shuffle mask to use. Shuffle-mask indexes are partitioned exactly as
//! in the paper:
//!
//! * `[0, 64)`   — case 1: six characters of 1–2 bytes each, placed into
//!   six 16-bit lanes (Fig. 2). 2⁶ = 64 masks.
//! * `[64, 145)` — case 2: four characters of 1–3 bytes each, placed into
//!   four 32-bit lanes (Fig. 3). 3⁴ = 81 masks.
//! * `[145, 209)`— case 3: three characters of 1–4 bytes each, placed
//!   into three 32-bit lanes incl. surrogate synthesis (Fig. 4).
//!   4³ = 64 masks.
//!
//! Lane layout (shared by all three cases): within its lane, a
//! character's bytes appear **last byte first** — byte 0 of the lane is
//! the final byte of the character, byte 1 the one before it, and so on;
//! absent bytes are `0x80` (which `pshufb` turns into zero). This makes
//! the bit-extraction masks of Figs. 2–4 uniform across character
//! lengths (see `transcode::utf8_to_utf16`).

use super::char_lens_from_mask;
use std::sync::LazyLock;

/// Number of shuffle masks (paper: "We need 209 shuffle masks").
pub const NUM_MASKS: usize = 209;
/// First index of case 2 (four chars × 1–3 bytes).
pub const CASE2_START: u8 = 64;
/// First index of case 3 (three chars × 1–4 bytes).
pub const CASE3_START: u8 = 145;

/// One main-table entry: bytes consumed by the window and the index of
/// the shuffle mask to apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Entry {
    /// Input bytes consumed by this 12-byte window.
    pub consumed: u8,
    /// Index of the shuffle mask to apply.
    pub idx: u8,
}

/// The tables: a 4096-entry main table (indexed by the 12-bit
/// end-of-character bitset) plus the 209 16-byte shuffle masks.
///
/// `shuf` is allocated at 256 entries (padding past `NUM_MASKS` is
/// never selected) so that indexing with the `u8` mask index provably
/// needs no bounds check in the hot loop.
pub struct Utf8ToUtf16Tables {
    /// The 4096-entry main table.
    pub main: [Entry; 4096],
    /// The 16-byte shuffle masks `main` refers to.
    pub shuf: [[u8; 16]; 256],
}

/// Lazily-constructed singleton (construction is cheap and deterministic;
/// see [`build_tables`]).
pub static TABLES: LazyLock<Utf8ToUtf16Tables> = LazyLock::new(build_tables);

/// Shuffle-mask index for case 1 from six lengths in `{1,2}`.
fn case1_idx(lens: &[u8]) -> u8 {
    let mut idx = 0u8;
    for k in 0..6 {
        idx |= (lens[k] - 1) << k;
    }
    idx
}

/// Shuffle-mask index for case 2 from four lengths in `{1,2,3}`.
fn case2_idx(lens: &[u8]) -> u8 {
    let mut idx = 0u16;
    let mut pow = 1u16;
    for k in 0..4 {
        idx += (lens[k] - 1) as u16 * pow;
        pow *= 3;
    }
    CASE2_START + idx as u8
}

/// Shuffle-mask index for case 3 from three lengths in `{1,2,3,4}`.
fn case3_idx(lens: &[u8]) -> u8 {
    let mut idx = 0u8;
    let mut pow = 1u8;
    for k in 0..3 {
        idx += (lens[k] - 1) * pow;
        pow *= 4;
    }
    CASE3_START + idx
}

/// Build the 16-byte shuffle mask for `nchars` characters of lengths
/// `lens`, each occupying a lane of `lane_width` bytes, bytes reversed
/// within the lane (`0x80` where absent).
fn build_mask(lens: &[u8], nchars: usize, lane_width: usize) -> [u8; 16] {
    let mut mask = [0x80u8; 16];
    let mut start = 0u8;
    for k in 0..nchars {
        let len = lens[k];
        let last = start + len - 1;
        for j in 0..len {
            mask[k * lane_width + j as usize] = last - j;
        }
        start += len;
    }
    mask
}

/// Construct the main table and shuffle masks.
///
/// For every 12-bit end-of-character bitset we extract the character
/// lengths ([`char_lens_from_mask`]) and pick, among the applicable
/// cases, the one consuming the most bytes (ties prefer case 1 over
/// case 2 over case 3 — fewer, cheaper lanes win at equal consumption).
/// Keys that describe invalid UTF-8 (a character longer than 4 bytes, or
/// fewer than three complete characters in 12 bytes — impossible for
/// valid input since windows start at character boundaries) fall back to
/// a safe entry that consumes at least one byte; the validating
/// transcoder rejects such inputs before the table is consulted.
pub fn build_tables() -> Utf8ToUtf16Tables {
    let mut shuf = [[0x80u8; 16]; 256];
    // Enumerate all masks up-front so each index is defined even if no
    // 12-bit key selects it.
    for code in 0..64u16 {
        let lens: Vec<u8> = (0..6).map(|k| ((code >> k) & 1) as u8 + 1).collect();
        shuf[case1_idx(&lens) as usize] = build_mask(&lens, 6, 2);
    }
    for code in 0..81u16 {
        let mut c = code;
        let lens: Vec<u8> = (0..4)
            .map(|_| {
                let l = (c % 3) as u8 + 1;
                c /= 3;
                l
            })
            .collect();
        shuf[case2_idx(&lens) as usize] = build_mask(&lens, 4, 4);
    }
    for code in 0..64u16 {
        let mut c = code;
        let lens: Vec<u8> = (0..3)
            .map(|_| {
                let l = (c % 4) as u8 + 1;
                c /= 4;
                l
            })
            .collect();
        shuf[case3_idx(&lens) as usize] = build_mask(&lens, 3, 4);
    }

    let mut main = [Entry { consumed: 1, idx: CASE3_START }; 4096];
    for key in 0..4096u32 {
        let (lens, n, _valid) = char_lens_from_mask(key, 12);
        // Candidate (consumed, idx) per case, if applicable.
        let mut best: Option<(u8, u8, u8)> = None; // (consumed, pref, idx)
        if n >= 6 && lens[..6].iter().all(|&l| l <= 2) {
            let consumed: u8 = lens[..6].iter().sum();
            best = Some((consumed, 2, case1_idx(&lens)));
        }
        if n >= 4 && lens[..4].iter().all(|&l| l <= 3) {
            let consumed: u8 = lens[..4].iter().sum();
            let cand = (consumed, 1, case2_idx(&lens));
            if best.map_or(true, |b| (cand.0, cand.1) > (b.0, b.1)) {
                best = Some(cand);
            }
        }
        if n >= 3 {
            // lens <= 4 by construction of char_lens_from_mask
            let consumed: u8 = lens[..3].iter().sum();
            let cand = (consumed, 0, case3_idx(&lens));
            if best.map_or(true, |b| (cand.0, cand.1) > (b.0, b.1)) {
                best = Some(cand);
            }
        }
        main[key as usize] = match best {
            Some((consumed, _, idx)) => Entry { consumed, idx },
            None => {
                // Invalid or boundary-degenerate key. Consume past the
                // first end-of-character bit (or one byte) using a
                // case-3 mask of padded 1-byte characters; output is
                // garbage but bounded — the validating path never gets
                // here on its own output.
                let consumed = if key == 0 { 12 } else { key.trailing_zeros() as u8 + 1 };
                let mut padded = [1u8; 3];
                for k in 0..n.min(3) {
                    padded[k] = lens[k];
                }
                Entry { consumed: consumed.max(1), idx: case3_idx(&padded) }
            }
        };
    }

    Utf8ToUtf16Tables { main, shuf }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_partition_matches_paper() {
        // 64 + 81 + 64 = 209 masks, partition boundaries as documented.
        assert_eq!(NUM_MASKS, 209);
        let all_one = [1u8; 6];
        assert_eq!(case1_idx(&all_one), 0);
        let all_two = [2u8; 6];
        assert_eq!(case1_idx(&all_two), 63);
        assert_eq!(case2_idx(&[1, 1, 1, 1]), 64);
        assert_eq!(case2_idx(&[3, 3, 3, 3]), 144);
        assert_eq!(case3_idx(&[1, 1, 1]), 145);
        assert_eq!(case3_idx(&[4, 4, 4]), 208);
    }

    #[test]
    fn ascii_key_consumes_six() {
        let t = &*TABLES;
        let e = t.main[0xFFF];
        assert_eq!(e.consumed, 6);
        assert!(e.idx < CASE2_START);
    }

    #[test]
    fn two_byte_key_consumes_twelve() {
        let t = &*TABLES;
        let e = t.main[0xAAA];
        assert_eq!(e.consumed, 12);
        assert!(e.idx < CASE2_START, "six 2-byte chars is case 1");
    }

    #[test]
    fn three_byte_key_is_case2() {
        let t = &*TABLES;
        let e = t.main[0x924];
        assert_eq!(e.consumed, 12);
        assert!(e.idx >= CASE2_START && e.idx < CASE3_START);
    }

    #[test]
    fn four_byte_key_is_case3() {
        let t = &*TABLES;
        let e = t.main[0x888];
        assert_eq!(e.consumed, 12);
        assert!(e.idx >= CASE3_START);
    }

    #[test]
    fn every_valid_key_consumes_at_least_three_bytes() {
        // For any key describing >= 3 complete chars of <= 4 bytes, the
        // entry must consume >= 3 bytes and never more than 12.
        let t = &*TABLES;
        for key in 0..4096u32 {
            let (lens, n, valid) = char_lens_from_mask(key, 12);
            let e = t.main[key as usize];
            assert!(e.consumed >= 1 && e.consumed <= 12, "key {key:03x}");
            if valid && n >= 3 {
                assert!(e.consumed >= lens[..3].iter().sum::<u8>().min(3), "key {key:03x}");
            }
        }
    }

    #[test]
    fn consumed_always_lands_on_char_boundary() {
        // If the entry consumes k bytes, bit k-1 of the key must be set
        // (the consumed region ends exactly at a character end) whenever
        // the key is structurally valid.
        let t = &*TABLES;
        for key in 0..4096u32 {
            let (_, n, valid) = char_lens_from_mask(key, 12);
            if !(valid && n >= 3) {
                continue;
            }
            let e = t.main[key as usize];
            assert_eq!(
                (key >> (e.consumed - 1)) & 1,
                1,
                "key {key:03x} consumed {} does not end a char",
                e.consumed
            );
        }
    }

    #[test]
    fn shuffle_mask_indices_stay_in_window() {
        let t = &*TABLES;
        for (i, mask) in t.shuf.iter().take(NUM_MASKS).enumerate() {
            for &b in mask {
                assert!(b == 0x80 || b < 12, "mask {i} has out-of-window index {b}");
            }
        }
    }

    #[test]
    fn case1_mask_layout() {
        // Six ASCII chars: lane k selects byte k into byte 2k, 0x80 high.
        let t = &*TABLES;
        let e = t.main[0xFFF];
        let m = t.shuf[e.idx as usize];
        for k in 0..6 {
            assert_eq!(m[2 * k], k as u8);
            assert_eq!(m[2 * k + 1], 0x80);
        }
    }

    #[test]
    fn case1_two_byte_layout_reverses_bytes() {
        // Six 2-byte chars: lane k = [2k+1, 2k] (last byte first).
        let t = &*TABLES;
        let e = t.main[0xAAA];
        let m = t.shuf[e.idx as usize];
        for k in 0..6 {
            assert_eq!(m[2 * k], 2 * k as u8 + 1);
            assert_eq!(m[2 * k + 1], 2 * k as u8);
        }
    }
}
