//! Tables for the UTF-16 → UTF-8 transcoder (§5, Algorithm 4).
//!
//! Two 256-entry tables, each entry a 16-byte shuffle mask plus a byte
//! count — 256 × 17 = 4352 bytes per table, 8704 bytes total, exactly the
//! paper's figure.
//!
//! * [`ONE_TWO`] — the 1–2-byte routine. The eight input words are
//!   *unpacked* into 16 bytes: byte `2i` holds the leading byte (or the
//!   ASCII byte itself) of word `i`, byte `2i+1` its continuation byte.
//!   The key is the 8-bit "word is ASCII" bitset; the mask compresses the
//!   needed 8–16 bytes to the front.
//! * [`ONE_TWO_THREE`] — the 1–3-byte routine, applied to half registers
//!   (four words expanded to four 32-bit lanes `[lead, cont1, cont2, _]`).
//!   The key packs two 4-bit bitsets: low nibble = "word < 0x80", high
//!   nibble = "word < 0x800"; the mask compresses the 4–12 needed bytes.

use std::sync::LazyLock;

/// A shuffle mask plus the number of output bytes it produces.
#[derive(Clone, Copy, Debug)]
pub struct CompressEntry {
    /// The `pshufb` compression mask.
    pub mask: [u8; 16],
    /// Output bytes the mask produces.
    pub count: u8,
}

/// Table for the 1–2-byte routine, keyed by the 8-bit ASCII bitset.
pub static ONE_TWO: LazyLock<[CompressEntry; 256]> = LazyLock::new(build_one_two);

/// Table for the 1–3-byte routine, keyed by `(ascii_mask) | (below_0x800_mask << 4)`
/// over four words.
pub static ONE_TWO_THREE: LazyLock<[CompressEntry; 256]> = LazyLock::new(build_one_two_three);

/// The [`ONE_TWO`] table widened for the 256-bit backend: every source
/// index is offset by 16 so the mask selects from the **high half** of a
/// 32-byte unpacked register through the two-source permute
/// [`crate::simd::shuffle32`] (the POWER `vperm` / AVX2
/// `vpermd`-class operation the 128-bit path never needs). Keyed by the
/// ASCII bitset of words 8–15.
pub static ONE_TWO_HI: LazyLock<[CompressEntry; 256]> = LazyLock::new(build_one_two_hi);

fn build_one_two_hi() -> [CompressEntry; 256] {
    let mut table = build_one_two();
    for entry in table.iter_mut() {
        for b in entry.mask.iter_mut() {
            if *b != 0x80 {
                *b += 16;
            }
        }
    }
    table
}

fn build_one_two() -> [CompressEntry; 256] {
    let mut table = [CompressEntry { mask: [0x80; 16], count: 0 }; 256];
    for key in 0..256usize {
        let mut mask = [0x80u8; 16];
        let mut out = 0usize;
        for word in 0..8 {
            let ascii = (key >> word) & 1 == 1;
            mask[out] = (2 * word) as u8;
            out += 1;
            if !ascii {
                mask[out] = (2 * word + 1) as u8;
                out += 1;
            }
        }
        table[key] = CompressEntry { mask, count: out as u8 };
    }
    table
}

fn build_one_two_three() -> [CompressEntry; 256] {
    let mut table = [CompressEntry { mask: [0x80; 16], count: 0 }; 256];
    for key in 0..256usize {
        let mut mask = [0x80u8; 16];
        let mut out = 0usize;
        for word in 0..4 {
            let one = (key >> word) & 1 == 1;
            let below_800 = (key >> (word + 4)) & 1 == 1;
            // Impossible combination (ASCII but not < 0x800) never occurs
            // at runtime; fill it as ASCII for safety.
            let len = if one {
                1
            } else if below_800 {
                2
            } else {
                3
            };
            for j in 0..len {
                mask[out] = (4 * word + j) as u8;
                out += 1;
            }
        }
        table[key] = CompressEntry { mask, count: out as u8 };
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_sizes_match_paper() {
        // 256 entries x (16-byte mask + 1 count byte) = 4352 bytes each.
        assert_eq!(ONE_TWO.len() * 17, 4352);
        assert_eq!(ONE_TWO_THREE.len() * 17, 4352);
    }

    #[test]
    fn one_two_all_ascii() {
        let e = ONE_TWO[0xFF];
        assert_eq!(e.count, 8);
        for i in 0..8 {
            assert_eq!(e.mask[i], 2 * i as u8);
        }
        assert!(e.mask[8..].iter().all(|&b| b == 0x80));
    }

    #[test]
    fn one_two_none_ascii() {
        let e = ONE_TWO[0x00];
        assert_eq!(e.count, 16);
        for i in 0..16 {
            assert_eq!(e.mask[i], i as u8);
        }
    }

    #[test]
    fn one_two_counts() {
        for key in 0..256usize {
            let expected = 8 + (8 - (key as u8).count_ones() as u8);
            assert_eq!(ONE_TWO[key].count, expected, "key {key:02x}");
        }
    }

    #[test]
    fn one_two_three_all_three_byte() {
        let e = ONE_TWO_THREE[0x00];
        assert_eq!(e.count, 12);
        // lanes [0,1,2], [4,5,6], [8,9,10], [12,13,14]
        let expected: Vec<u8> = (0..4).flat_map(|w| vec![4 * w, 4 * w + 1, 4 * w + 2]).collect();
        assert_eq!(&e.mask[..12], &expected[..]);
    }

    #[test]
    fn one_two_three_all_ascii() {
        let e = ONE_TWO_THREE[0xFF];
        assert_eq!(e.count, 4);
        assert_eq!(&e.mask[..4], &[0, 4, 8, 12]);
    }

    #[test]
    fn one_two_hi_is_one_two_offset_by_sixteen() {
        for key in 0..256usize {
            let lo = ONE_TWO[key];
            let hi = ONE_TWO_HI[key];
            assert_eq!(lo.count, hi.count, "key {key:02x}");
            for i in 0..16 {
                if lo.mask[i] == 0x80 {
                    assert_eq!(hi.mask[i], 0x80, "key {key:02x} lane {i}");
                } else {
                    assert_eq!(hi.mask[i], lo.mask[i] + 16, "key {key:02x} lane {i}");
                    assert!(hi.mask[i] < 32, "key {key:02x} lane {i}");
                }
            }
        }
    }

    #[test]
    fn one_two_three_mixed() {
        // word0 ascii, word1 two-byte, word2 three-byte, word3 two-byte:
        // one-mask = 0b0001, below-800-mask = 0b1011
        let key = 0b0001 | (0b1011 << 4);
        let e = ONE_TWO_THREE[key];
        assert_eq!(e.count, 1 + 2 + 3 + 2);
        assert_eq!(&e.mask[..8], &[0, 4, 5, 8, 9, 10, 12, 13]);
    }
}
