//! SIMD counting subsystem: exact-size output predictors and code-point
//! counters, generic over the [`VectorBackend`] like the converters.
//!
//! The paper's follow-up (*Unicode at Gigabytes per Second*,
//! arXiv:2111.08692) observes that UTF-8 length and code-point counting
//! are themselves SIMD problems: a UTF-8 continuation byte is any byte
//! `b` with `(b & 0xC0) == 0x80`, so code points are a movemask +
//! popcount away, and the UTF-16 word a byte produces is fully
//! determined by its top nibble. This module provides those kernels so
//! the `*_to_vec_exact` allocation path (see
//! [`crate::transcode::Utf8ToUtf16::convert_to_vec_exact`]) can size
//! its output precisely at near-zero cost instead of allocating — and
//! zero-initializing — the worst case.
//!
//! ### Kernels
//!
//! | function | counts | unit |
//! |---|---|---|
//! | [`utf16_len_from_utf8`] | non-continuation bytes + 4-byte leads | UTF-16 words |
//! | [`utf8_len_from_utf16`] | 1/2/3 bytes per word, 4 per surrogate pair | UTF-8 bytes |
//! | [`count_utf8_code_points`] | non-continuation bytes | code points |
//! | [`count_utf16_code_points`] | words minus high surrogates | code points |
//! | [`utf8_len_from_latin1`] | 1 per ASCII byte, 2 per `>= 0x80` | UTF-8 bytes |
//! | [`latin1_len_from_utf8`] | code points (= non-continuation bytes) | Latin-1 bytes |
//!
//! Each exists in three flavors: a scalar reference (`*_scalar`), a
//! backend-generic SIMD kernel (`*_with::<B>`), and a runtime-dispatched
//! entry point (the bare name) that resolves the widest usable backend
//! once — the same policy as the engine registry's `best` alias. The
//! registry surfaces all of them per key via [`kernel_entries`] /
//! `Registry::count_entries`.
//!
//! ### Semantics on invalid input
//!
//! The predictors are *total*: they accept arbitrary bytes/words and
//! stay upper bounds for every engine in the crate. The conventions
//! match the original scalar predictors exactly (asserted by the
//! differential suite in `rust/tests/counting.rs`):
//!
//! * UTF-8: continuation bytes count 0 words, every other byte 1, bytes
//!   `>= 0xF0` one extra (the low half of a surrogate pair).
//! * UTF-16: every **unpaired** surrogate counts 3 bytes — the width of
//!   both U+FFFD (lossy replacement) and the raw WTF-8 encoding the
//!   non-validating engine emits; a proper pair counts 4.
//!
//! ### Algorithm notes
//!
//! The UTF-8 kernels reuse the converters' 64-byte all-ASCII block fast
//! path ([`is_ascii_block`]: one OR-reduction instead of three
//! classification movemasks), then classify a backend register at a
//! time: `continuation = msb(b) & !(b >= 0xC0)` with the `>=`
//! comparisons done as `saturating_sub` + movemask
//! ([`SimdBytes::ge_mask`]).
//!
//! The UTF-16 kernel computes five `lt_mask` movemasks per register
//! (`0x80`, `0x800`, and the three surrogate-range bounds) and counts
//! `lanes + popcount(>= 0x80) + popcount(>= 0x800) - 2 * popcount(pairs)`
//! where `pairs = ((high << 1) | carry) & low` — a high-surrogate lane
//! immediately followed by a low-surrogate lane, with a one-bit carry
//! across register boundaries (and a `-2` adjustment when the carry
//! meets a low surrogate at the head of the scalar tail). This is exact
//! for arbitrary input because a high surrogate can never itself be the
//! second element of a pair, so "high followed by low" is precisely the
//! paired case of the scalar reference.

use crate::simd::{is_ascii_block, SimdBytes, SimdWords, VectorBackend, V128, V256, V512};
use std::sync::LazyLock;

// ---------------------------------------------------------------------------
// Scalar references.

/// Scalar reference: UTF-16 words needed for `src` (see module docs for
/// the invalid-input convention). One pass, byte at a time.
pub fn utf16_len_from_utf8_scalar(src: &[u8]) -> usize {
    // words = #non-continuation bytes + #4-byte leads
    let mut n = 0usize;
    for &b in src {
        n += ((b & 0xC0) != 0x80) as usize;
        n += (b >= 0xF0) as usize;
    }
    n
}

/// Scalar reference: code points in `src` (= non-continuation bytes;
/// exact for valid UTF-8, total on garbage).
pub fn count_utf8_code_points_scalar(src: &[u8]) -> usize {
    let mut n = 0usize;
    for &b in src {
        n += ((b & 0xC0) != 0x80) as usize;
    }
    n
}

/// Scalar reference: UTF-8 bytes needed for `src`.
///
/// Exact for valid input (a surrogate *pair* contributes 4 bytes);
/// every **unpaired** surrogate counts 3 (see module docs).
pub fn utf8_len_from_utf16_scalar(src: &[u16]) -> usize {
    let mut n = 0usize;
    let mut i = 0usize;
    while i < src.len() {
        let w = src[i];
        n += if w < 0x80 {
            1
        } else if w < 0x800 {
            2
        } else if (0xD800..0xDC00).contains(&w) {
            if i + 1 < src.len() && (0xDC00..0xE000).contains(&src[i + 1]) {
                // Properly paired: the pair is one 4-byte character.
                i += 1;
                4
            } else {
                3 // unpaired high surrogate
            }
        } else {
            // BMP character, or an unpaired low surrogate (3 either way).
            3
        };
        i += 1;
    }
    n
}

/// Scalar reference: code points in `src` (words minus high
/// surrogates — each pair's high word starts a code point its low word
/// completes; exact for valid UTF-16, total on garbage where it counts
/// an unpaired low surrogate as one would-be replacement).
pub fn count_utf16_code_points_scalar(src: &[u16]) -> usize {
    src.len() - src.iter().filter(|&&w| (0xD800..0xDC00).contains(&w)).count()
}

// ---------------------------------------------------------------------------
// Backend-generic SIMD kernels.

/// SIMD [`utf16_len_from_utf8_scalar`] on backend `B`: 64-byte ASCII
/// blocks short-circuit, otherwise one register = three movemasks and
/// two popcounts. Identical result on arbitrary input.
pub fn utf16_len_from_utf8_with<B: VectorBackend>(src: &[u8]) -> usize {
    let w = B::WIDTH;
    let mut n = 0usize;
    let mut p = 0usize;
    while p + 64 <= src.len() {
        let block: &[u8; 64] = src[p..p + 64].try_into().unwrap();
        if is_ascii_block(block) {
            // 64 ASCII bytes are 64 words: one OR-reduce, no classify.
            n += 64;
            p += 64;
            continue;
        }
        let mut off = 0usize;
        while off + w <= 64 {
            let v = <B::Bytes as SimdBytes>::load(&src[p + off..]);
            let non_ascii = v.movemask();
            let ge_c0 = v.ge_mask(0xC0);
            let ge_f0 = v.ge_mask(0xF0);
            // continuation <=> high bit set and below 0xC0
            let cont = non_ascii & !ge_c0;
            n += w - cont.count_ones() as usize + ge_f0.count_ones() as usize;
            off += w;
        }
        p += 64;
    }
    n + utf16_len_from_utf8_scalar(&src[p..])
}

/// SIMD [`count_utf8_code_points_scalar`] on backend `B`.
pub fn count_utf8_code_points_with<B: VectorBackend>(src: &[u8]) -> usize {
    let w = B::WIDTH;
    let mut n = 0usize;
    let mut p = 0usize;
    while p + 64 <= src.len() {
        let block: &[u8; 64] = src[p..p + 64].try_into().unwrap();
        if is_ascii_block(block) {
            n += 64;
            p += 64;
            continue;
        }
        let mut off = 0usize;
        while off + w <= 64 {
            let v = <B::Bytes as SimdBytes>::load(&src[p + off..]);
            let cont = v.movemask() & !v.ge_mask(0xC0);
            n += w - cont.count_ones() as usize;
            off += w;
        }
        p += 64;
    }
    n + count_utf8_code_points_scalar(&src[p..])
}

/// SIMD [`utf8_len_from_utf16_scalar`] on backend `B`: five `lt_mask`
/// movemasks per register, pair detection by mask shift with a one-bit
/// carry across registers (see module docs for why this is exact).
pub fn utf8_len_from_utf16_with<B: VectorBackend>(src: &[u16]) -> usize {
    let lanes = B::WIDTH / 2;
    let all: u32 = if lanes == 32 { u32::MAX } else { (1u32 << lanes) - 1 };
    let mut n = 0usize;
    let mut p = 0usize;
    // Set iff the last lane of the previous register held a high
    // surrogate (a pair may straddle the register boundary).
    let mut carry: u32 = 0;
    while p + lanes <= src.len() {
        let v = <B::Words as SimdWords>::load(&src[p..]);
        let lt_80 = v.lt_mask(<B::Words as SimdWords>::splat(0x80)).movemask();
        let lt_800 = v.lt_mask(<B::Words as SimdWords>::splat(0x800)).movemask();
        let lt_d8 = v.lt_mask(<B::Words as SimdWords>::splat(0xD800)).movemask();
        let lt_dc = v.lt_mask(<B::Words as SimdWords>::splat(0xDC00)).movemask();
        let lt_e0 = v.lt_mask(<B::Words as SimdWords>::splat(0xE000)).movemask();
        let ge_80 = all & !lt_80;
        let ge_800 = all & !lt_800;
        let high = lt_dc & !lt_d8;
        let low = lt_e0 & !lt_dc;
        // 1 + (>= 0x80) + (>= 0x800) counts every surrogate word as 3;
        // each high-immediately-before-low pair is 4, not 6.
        let pairs = ((high << 1) | carry) & low;
        n += lanes + ge_80.count_ones() as usize + ge_800.count_ones() as usize
            - 2 * pairs.count_ones() as usize;
        carry = (high >> (lanes - 1)) & 1;
        p += lanes;
    }
    n += utf8_len_from_utf16_scalar(&src[p..]);
    if carry == 1 && p < src.len() && (0xDC00..0xE000).contains(&src[p]) {
        // The tail counted this low surrogate as unpaired (3) and the
        // SIMD part counted its high as unpaired (3); the pair is 4.
        n -= 2;
    }
    n
}

/// SIMD [`count_utf16_code_points_scalar`] on backend `B` (no carry
/// needed: the count only subtracts high-surrogate lanes).
pub fn count_utf16_code_points_with<B: VectorBackend>(src: &[u16]) -> usize {
    let lanes = B::WIDTH / 2;
    let mut n = 0usize;
    let mut p = 0usize;
    while p + lanes <= src.len() {
        let v = <B::Words as SimdWords>::load(&src[p..]);
        let lt_d8 = v.lt_mask(<B::Words as SimdWords>::splat(0xD800)).movemask();
        let lt_dc = v.lt_mask(<B::Words as SimdWords>::splat(0xDC00)).movemask();
        let high = lt_dc & !lt_d8;
        n += lanes - high.count_ones() as usize;
        p += lanes;
    }
    n + count_utf16_code_points_scalar(&src[p..])
}

// ---------------------------------------------------------------------------
// Latin-1 predictors. Latin-1 is a fixed-width superset-of-ASCII byte
// encoding, so its predictors are one movemask away: a Latin-1 byte
// becomes 1 UTF-8 byte when ASCII and 2 otherwise, and always exactly
// one UTF-16 word / UTF-32 value.

/// Scalar reference: UTF-8 bytes needed for Latin-1 input (1 per ASCII
/// byte, 2 per byte `>= 0x80`). Total — every byte slice is valid
/// Latin-1.
pub fn utf8_len_from_latin1_scalar(src: &[u8]) -> usize {
    let mut n = src.len();
    for &b in src {
        n += (b >= 0x80) as usize;
    }
    n
}

/// SIMD [`utf8_len_from_latin1_scalar`] on backend `B`: 64-byte ASCII
/// blocks short-circuit, otherwise one movemask + popcount per
/// register.
pub fn utf8_len_from_latin1_with<B: VectorBackend>(src: &[u8]) -> usize {
    let w = B::WIDTH;
    let mut n = 0usize;
    let mut p = 0usize;
    while p + 64 <= src.len() {
        let block: &[u8; 64] = src[p..p + 64].try_into().unwrap();
        if is_ascii_block(block) {
            n += 64;
            p += 64;
            continue;
        }
        let mut off = 0usize;
        while off + w <= 64 {
            let v = <B::Bytes as SimdBytes>::load(&src[p + off..]);
            n += w + v.movemask().count_ones() as usize;
            off += w;
        }
        p += 64;
    }
    n + utf8_len_from_latin1_scalar(&src[p..])
}

/// UTF-8 bytes needed for Latin-1 input, on the widest usable backend.
#[inline]
pub fn utf8_len_from_latin1(src: &[u8]) -> usize {
    match crate::simd::best_key() {
        k if k == V512::KEY => utf8_len_from_latin1_with::<V512>(src),
        k if k == V256::KEY => utf8_len_from_latin1_with::<V256>(src),
        _ => utf8_len_from_latin1_with::<V128>(src),
    }
}

/// Scalar reference: Latin-1 bytes needed for UTF-8 input — one per
/// code point, i.e. exactly [`count_utf8_code_points_scalar`]. An
/// upper bound on what [`crate::transcode::latin1::utf8_to_latin1`]
/// writes for *any* input (conversion stops at the first
/// non-convertible sequence).
#[inline]
pub fn latin1_len_from_utf8_scalar(src: &[u8]) -> usize {
    count_utf8_code_points_scalar(src)
}

/// Latin-1 bytes needed for UTF-8 input, on the widest usable backend
/// (the code-point count — see [`latin1_len_from_utf8_scalar`]).
#[inline]
pub fn latin1_len_from_utf8(src: &[u8]) -> usize {
    count_utf8_code_points(src)
}

/// UTF-16 words needed for Latin-1 input: exactly one per byte (no
/// Latin-1 value needs a surrogate pair).
#[inline]
pub fn utf16_len_from_latin1(src: &[u8]) -> usize {
    src.len()
}

/// Latin-1 bytes needed for UTF-16 input: one per word — exact for
/// convertible input (every code point `<= U+00FF` is one word and one
/// byte) and an upper bound otherwise (conversion stops at the first
/// out-of-range word).
#[inline]
pub fn latin1_len_from_utf16(src: &[u16]) -> usize {
    src.len()
}

// ---------------------------------------------------------------------------
// UTF-32 predictors (fixed-width input: the branch-free scalar loops
// autovectorize; no table machinery is needed).

/// UTF-8 bytes needed for UTF-32 input (exact for valid input; values
/// above U+10FFFF or in the surrogate gap are counted by magnitude,
/// keeping the estimate an upper bound).
pub fn utf8_len_from_utf32(src: &[u32]) -> usize {
    let mut n = 0usize;
    for &c in src {
        n += 1
            + (c >= 0x80) as usize
            + (c >= 0x800) as usize
            + (c >= 0x10000) as usize;
    }
    n
}

/// UTF-16 words needed for UTF-32 input (2 per supplemental-plane
/// value; exact for valid input).
pub fn utf16_len_from_utf32(src: &[u32]) -> usize {
    let mut n = src.len();
    for &c in src {
        n += (c >= 0x10000) as usize;
    }
    n
}

// ---------------------------------------------------------------------------
// Runtime dispatch + registry surface.

/// One named set of counting kernels (the counting analogue of a
/// registry engine entry). `fn` pointers so the set is enumerable and
/// benchable without generics.
#[derive(Clone, Copy)]
pub struct CountKernels {
    /// `"scalar"`, `"simd128"`, `"simd256"`, `"simd512"` or `"best"`.
    pub key: &'static str,
    /// UTF-16 words needed for UTF-8 input.
    pub utf16_len_from_utf8: fn(&[u8]) -> usize,
    /// UTF-8 bytes needed for UTF-16 input.
    pub utf8_len_from_utf16: fn(&[u16]) -> usize,
    /// Code points in UTF-8 input.
    pub count_utf8_code_points: fn(&[u8]) -> usize,
    /// Code points in UTF-16 input.
    pub count_utf16_code_points: fn(&[u16]) -> usize,
}

impl std::fmt::Debug for CountKernels {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CountKernels").field("key", &self.key).finish()
    }
}

/// The scalar reference set.
pub static SCALAR_KERNELS: CountKernels = CountKernels {
    key: "scalar",
    utf16_len_from_utf8: utf16_len_from_utf8_scalar,
    utf8_len_from_utf16: utf8_len_from_utf16_scalar,
    count_utf8_code_points: count_utf8_code_points_scalar,
    count_utf16_code_points: count_utf16_code_points_scalar,
};

/// The 128-bit kernel set.
pub static SIMD128_KERNELS: CountKernels = CountKernels {
    key: "simd128",
    utf16_len_from_utf8: utf16_len_from_utf8_with::<V128>,
    utf8_len_from_utf16: utf8_len_from_utf16_with::<V128>,
    count_utf8_code_points: count_utf8_code_points_with::<V128>,
    count_utf16_code_points: count_utf16_code_points_with::<V128>,
};

/// The 256-bit kernel set.
pub static SIMD256_KERNELS: CountKernels = CountKernels {
    key: "simd256",
    utf16_len_from_utf8: utf16_len_from_utf8_with::<V256>,
    utf8_len_from_utf16: utf8_len_from_utf16_with::<V256>,
    count_utf8_code_points: count_utf8_code_points_with::<V256>,
    count_utf16_code_points: count_utf16_code_points_with::<V256>,
};

/// The 512-bit kernel set.
pub static SIMD512_KERNELS: CountKernels = CountKernels {
    key: "simd512",
    utf16_len_from_utf8: utf16_len_from_utf8_with::<V512>,
    utf8_len_from_utf16: utf8_len_from_utf16_with::<V512>,
    count_utf8_code_points: count_utf8_code_points_with::<V512>,
    count_utf16_code_points: count_utf16_code_points_with::<V512>,
};

/// The `best` set: the widest backend worth running here, resolved once
/// with the exact policy of the engine registry's `best` alias
/// ([`crate::simd::best_key`] — the ISA compiled in *and* detected).
static BEST: LazyLock<CountKernels> = LazyLock::new(|| {
    let resolved = match crate::simd::best_key() {
        k if k == V512::KEY => SIMD512_KERNELS,
        k if k == V256::KEY => SIMD256_KERNELS,
        _ => SIMD128_KERNELS,
    };
    CountKernels { key: "best", ..resolved }
});

/// Every kernel set, in registry order (`scalar`, `simd128`, `simd256`,
/// `simd512`, `best`). Benches, tests and `Registry::count_entries`
/// enumerate this.
pub fn kernel_entries() -> [&'static CountKernels; 5] {
    [&SCALAR_KERNELS, &SIMD128_KERNELS, &SIMD256_KERNELS, &SIMD512_KERNELS, &*BEST]
}

/// UTF-16 words needed for `src`, on the widest usable backend.
#[inline]
pub fn utf16_len_from_utf8(src: &[u8]) -> usize {
    (BEST.utf16_len_from_utf8)(src)
}

/// UTF-8 bytes needed for `src`, on the widest usable backend.
#[inline]
pub fn utf8_len_from_utf16(src: &[u16]) -> usize {
    (BEST.utf8_len_from_utf16)(src)
}

/// Code points in (valid) UTF-8, on the widest usable backend.
#[inline]
pub fn count_utf8_code_points(src: &[u8]) -> usize {
    (BEST.count_utf8_code_points)(src)
}

/// Code points in (valid) UTF-16, on the widest usable backend.
#[inline]
pub fn count_utf16_code_points(src: &[u16]) -> usize {
    (BEST.count_utf16_code_points)(src)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLES: &[&str] = &[
        "",
        "a",
        "plain ascii only, long enough to cross one 64-byte block boundary!!",
        "héllo wörld",
        "пример текста на русском языке, длиннее шестидесяти четырёх байт",
        "漢字テスト、これは六十四バイトを超える長さの文字列です。続く。",
        "🙂🚀🌍💡🔥🎉🙂🚀🌍💡🔥🎉🙂🚀🌍💡🔥🎉",
        "mixed é漢🙂 text with a bit of everything: ascii, éé, 漢字, 🚀🚀 end",
    ];

    #[test]
    fn utf8_kernels_match_std_on_valid_text() {
        for text in SAMPLES {
            let repeated = text.repeat(7);
            let b = repeated.as_bytes();
            let words = repeated.encode_utf16().count();
            let cps = repeated.chars().count();
            for k in kernel_entries() {
                assert_eq!((k.utf16_len_from_utf8)(b), words, "{} {text}", k.key);
                assert_eq!((k.count_utf8_code_points)(b), cps, "{} {text}", k.key);
            }
        }
    }

    #[test]
    fn utf16_kernels_match_std_on_valid_text() {
        for text in SAMPLES {
            let repeated = text.repeat(7);
            let units: Vec<u16> = repeated.encode_utf16().collect();
            for k in kernel_entries() {
                assert_eq!((k.utf8_len_from_utf16)(&units), repeated.len(), "{}", k.key);
                assert_eq!(
                    (k.count_utf16_code_points)(&units),
                    repeated.chars().count(),
                    "{}",
                    k.key
                );
            }
        }
    }

    #[test]
    fn simd_matches_scalar_on_garbage_bytes() {
        let mut state = 0x1234_5678_9ABC_DEF0u64;
        for len in [0usize, 1, 15, 16, 63, 64, 65, 127, 128, 200, 513] {
            let mut soup = vec![0u8; len];
            for b in soup.iter_mut() {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                *b = (state >> 33) as u8;
            }
            let words = utf16_len_from_utf8_scalar(&soup);
            let cps = count_utf8_code_points_scalar(&soup);
            for k in kernel_entries() {
                assert_eq!((k.utf16_len_from_utf8)(&soup), words, "{} len={len}", k.key);
                assert_eq!((k.count_utf8_code_points)(&soup), cps, "{} len={len}", k.key);
            }
        }
    }

    #[test]
    fn unpaired_surrogates_follow_the_three_byte_convention() {
        let cases: &[(&[u16], usize)] = &[
            (&[0xDC00], 3),                  // lone low
            (&[0xD800], 3),                  // lone high at end
            (&[0xD800, 0x41], 4),            // high + non-low
            (&[0xD83D, 0xDE42], 4),          // proper pair
            (&[0xDC00, 0xD800], 6),          // reversed: two unpaired
            (&[0xD800, 0xD800, 0xDC00], 7),  // high then proper pair
            (&[0xD800, 0xDC00, 0xDC00], 7),  // pair then lone low
        ];
        for &(words, expected) in cases {
            for k in kernel_entries() {
                assert_eq!((k.utf8_len_from_utf16)(words), expected, "{} {words:04x?}", k.key);
            }
        }
    }

    #[test]
    fn surrogate_pairs_straddling_register_boundaries() {
        // A pair split across lanes 7|8 and 15|16 (both widths'
        // boundaries), plus the SIMD-part/scalar-tail seam.
        for pos in 0..40 {
            for pat in [
                &[0xD800u16, 0xDC00][..],
                &[0xD800, 0xD800, 0xDC00][..],
                &[0xDC00, 0xD800][..],
                &[0xD800][..],
            ] {
                let mut v = vec![0x41u16; pos];
                v.extend_from_slice(pat);
                v.extend(std::iter::repeat(0x42).take(7));
                let expected = utf8_len_from_utf16_scalar(&v);
                for k in kernel_entries() {
                    assert_eq!(
                        (k.utf8_len_from_utf16)(&v),
                        expected,
                        "{} pos={pos} pat={pat:04x?}",
                        k.key
                    );
                }
            }
        }
    }

    #[test]
    fn utf32_predictors_match_std() {
        for text in SAMPLES {
            let cps: Vec<u32> = text.chars().map(|c| c as u32).collect();
            assert_eq!(utf8_len_from_utf32(&cps), text.len(), "{text}");
            assert_eq!(utf16_len_from_utf32(&cps), text.encode_utf16().count(), "{text}");
        }
    }

    #[test]
    fn latin1_predictors_match_std() {
        // Every byte value is valid Latin-1; `b as char` is the oracle.
        let mut state = 0x0DDB_A11_5EEDu64;
        for len in [0usize, 1, 15, 16, 17, 63, 64, 65, 127, 200, 513] {
            let mut bytes = vec![0u8; len];
            for b in bytes.iter_mut() {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                *b = (state >> 33) as u8;
            }
            let text: String = bytes.iter().map(|&b| b as char).collect();
            let expected = text.len(); // UTF-8 length
            assert_eq!(utf8_len_from_latin1_scalar(&bytes), expected, "len={len}");
            assert_eq!(utf8_len_from_latin1_with::<V128>(&bytes), expected, "len={len}");
            assert_eq!(utf8_len_from_latin1_with::<V256>(&bytes), expected, "len={len}");
            assert_eq!(utf8_len_from_latin1_with::<V512>(&bytes), expected, "len={len}");
            assert_eq!(utf8_len_from_latin1(&bytes), expected, "len={len}");
            assert_eq!(latin1_len_from_utf8(text.as_bytes()), bytes.len(), "len={len}");
            assert_eq!(utf16_len_from_latin1(&bytes), text.encode_utf16().count());
            let words: Vec<u16> = text.encode_utf16().collect();
            assert_eq!(latin1_len_from_utf16(&words), bytes.len());
        }
    }

    #[test]
    fn best_resolves_like_the_engine_registry() {
        let best = kernel_entries()[4];
        assert_eq!(best.key, "best");
        assert_eq!(utf16_len_from_utf8(b"smoke"), 5);
        assert_eq!(count_utf16_code_points(&[0x41, 0xD83D, 0xDE42]), 2);
    }
}
