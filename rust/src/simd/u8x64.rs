//! 64-lane byte vector (the 512-bit side of the backend layer).

use super::backend::{kl_step_portable, SimdBytes};
use super::{U8x16, U8x32};

/// A 64-byte SIMD value with AVX-512BW/VBMI-equivalent semantics.
///
/// Loop-based operations autovectorize at `opt-level=3`; the operations
/// LLVM cannot synthesize from loops carry explicit `core::arch`
/// implementations:
///
/// * `movemask` — `vpmovb2m` (one `kmov`-able 64-bit mask per register),
///   gated on `target_feature = "avx512bw"`.
/// * `shuffle` / `lookup16` — `vpshufb` at 512 bits (per 16-byte
///   quarter), gated on `avx512bw`.
/// * `prev` / [`U8x64::permute2`] — the `vpermt2b`-class two-source
///   64-lane permute (`_mm512_permutex2var_epi8`), gated on
///   `avx512vbmi`. This is the cross-register byte permute Clausecker &
///   Lemire's AVX-512 transcoder is built around.
/// * [`U8x64::load_partial`] / [`U8x64::store_partial`] — masked
///   loads/stores (`vmovdqu8` with a `k` mask), gated on `avx512bw`, so
///   tails shorter than a register cost one masked memory operation
///   instead of a scalar loop.
///
/// Note the `vpshufb` convention: at 64 lanes [`U8x64::shuffle`] and
/// [`U8x64::lookup16`] operate **per 16-byte quarter** (lane `i`
/// selects from its own quarter), exactly like `_mm512_shuffle_epi8`.
/// Cross-quarter permutes go through [`U8x64::permute2`] explicitly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(transparent)]
pub struct U8x64(pub [u8; 64]);

impl U8x64 {
    /// The all-zero vector.
    pub const ZERO: U8x64 = U8x64([0; 64]);

    /// Load 64 bytes from the start of `src` (must have length >= 64).
    #[inline]
    pub fn load(src: &[u8]) -> U8x64 {
        let mut v = [0u8; 64];
        v.copy_from_slice(&src[..64]);
        U8x64(v)
    }

    /// Load `src.len()` bytes (must be <= 64) into the low lanes; the
    /// remaining lanes are zero. On AVX-512BW this is one masked load
    /// (`vmovdqu8 {k}{z}`) — the "exact tail" primitive — and a
    /// zero-padded copy elsewhere.
    #[inline]
    pub fn load_partial(src: &[u8]) -> U8x64 {
        debug_assert!(src.len() <= 64);
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512bw"))]
        // SAFETY: avx512bw is statically enabled by this cfg; the
        // masked load reads only the `n` bytes whose mask bit is set
        // (`(1 << n) - 1`, all of `src`; `u64::MAX` when n == 64), and
        // the store writes 64 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let n = src.len().min(64);
            let mask: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
            let r = _mm512_maskz_loadu_epi8(mask, src.as_ptr() as *const i8);
            let mut out = [0u8; 64];
            _mm512_storeu_si512(out.as_mut_ptr() as *mut __m512i, r);
            return U8x64(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 64];
            v[..src.len()].copy_from_slice(src);
            U8x64(v)
        }
    }

    /// Broadcast a single byte to all lanes.
    #[inline]
    pub fn splat(b: u8) -> U8x64 {
        U8x64([b; 64])
    }

    /// Store into the start of `dst` (must have length >= 64).
    #[inline]
    pub fn store(self, dst: &mut [u8]) {
        dst[..64].copy_from_slice(&self.0);
    }

    /// Store the low `dst.len().min(64)` lanes. On AVX-512BW this is one
    /// masked store (`vmovdqu8 {k}`), so a short destination costs no
    /// scalar loop and no over-write beyond `dst`.
    #[inline]
    pub fn store_partial(self, dst: &mut [u8]) {
        let n = dst.len().min(64);
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512bw"))]
        // SAFETY: avx512bw is statically enabled by this cfg; the load
        // reads 64 bytes from `self.0` (`[u8; 64]`) and the masked
        // store writes only the `n = dst.len().min(64)` bytes whose
        // mask bit is set — all within `dst`.
        unsafe {
            use core::arch::x86_64::*;
            let mask: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
            let v = _mm512_loadu_si512(self.0.as_ptr() as *const __m512i);
            _mm512_mask_storeu_epi8(dst.as_mut_ptr() as *mut i8, mask, v);
            return;
        }
        #[allow(unreachable_code)]
        dst[..n].copy_from_slice(&self.0[..n]);
    }

    /// The two 32-byte halves, low half first.
    #[inline]
    pub fn to_halves(self) -> (U8x32, U8x32) {
        let mut lo = [0u8; 32];
        let mut hi = [0u8; 32];
        lo.copy_from_slice(&self.0[..32]);
        hi.copy_from_slice(&self.0[32..]);
        (U8x32(lo), U8x32(hi))
    }

    /// The four 16-byte quarters, in lane order.
    #[inline]
    pub fn to_quarters(self) -> [U8x16; 4] {
        core::array::from_fn(|q| {
            let mut v = [0u8; 16];
            v.copy_from_slice(&self.0[16 * q..16 * q + 16]);
            U8x16(v)
        })
    }

    /// Lane-wise bitwise AND (`vpandq`).
    #[inline]
    pub fn and(self, rhs: U8x64) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..64 {
            v[i] = self.0[i] & rhs.0[i];
        }
        U8x64(v)
    }

    /// Lane-wise bitwise OR (`vporq`).
    #[inline]
    pub fn or(self, rhs: U8x64) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..64 {
            v[i] = self.0[i] | rhs.0[i];
        }
        U8x64(v)
    }

    /// Lane-wise bitwise XOR (`vpxorq`).
    #[inline]
    pub fn xor(self, rhs: U8x64) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..64 {
            v[i] = self.0[i] ^ rhs.0[i];
        }
        U8x64(v)
    }

    /// Lane-wise unsigned saturating subtraction (`vpsubusb`).
    #[inline]
    pub fn saturating_sub(self, rhs: U8x64) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..64 {
            v[i] = self.0[i].saturating_sub(rhs.0[i]);
        }
        U8x64(v)
    }

    /// Lane-wise logical shift right by a constant.
    #[inline]
    pub fn shr<const N: u32>(self) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..64 {
            v[i] = self.0[i] >> N;
        }
        U8x64(v)
    }

    /// `vpmovb2m`: bit `i` of the result is the MSB of lane `i`. At 64
    /// lanes the mask exactly fills a `u64` — the width the 64-byte
    /// block algorithms (Algorithm 3's end-of-character bitsets) want,
    /// with no widening step.
    #[inline]
    pub fn movemask(self) -> u64 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512bw"))]
        // SAFETY: avx512bw is statically enabled by this cfg; the load
        // reads exactly 64 bytes from `self.0`, a `[u8; 64]`.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm512_loadu_si512(self.0.as_ptr() as *const __m512i);
            return _mm512_movepi8_mask(a);
        }
        #[allow(unreachable_code)]
        {
            let mut m = 0u64;
            for i in 0..64 {
                m |= ((self.0[i] >> 7) as u64) << i;
            }
            m
        }
    }

    /// `vpshufb` at 512 bits: per 16-byte quarter, lane `i` is zero when
    /// `idx[i] & 0x80` is set, else byte `idx[i] & 0x0F` of lane `i`'s
    /// own quarter (the `_mm512_shuffle_epi8` convention).
    #[inline]
    pub fn shuffle(self, idx: U8x64) -> U8x64 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512bw"))]
        // SAFETY: avx512bw is statically enabled by this cfg; the loads
        // read 64 bytes each from `self.0`/`idx.0` (`[u8; 64]`) and the
        // store writes 64 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm512_loadu_si512(self.0.as_ptr() as *const __m512i);
            let b = _mm512_loadu_si512(idx.0.as_ptr() as *const __m512i);
            let r = _mm512_shuffle_epi8(a, b);
            let mut out = [0u8; 64];
            _mm512_storeu_si512(out.as_mut_ptr() as *mut __m512i, r);
            return U8x64(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 64];
            for i in 0..64 {
                let j = idx.0[i];
                v[i] = if j & 0x80 != 0 {
                    0
                } else {
                    self.0[(i & 0x30) | (j & 0x0F) as usize]
                };
            }
            U8x64(v)
        }
    }

    /// Nibble-table lookup: the 16-byte table broadcast to all four
    /// quarters, then `vpshufb`. Every lane of `self` must be in
    /// `[0, 16)`.
    #[inline]
    pub fn lookup16(self, table: &[u8; 16]) -> U8x64 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512bw"))]
        // SAFETY: avx512bw (which implies sse2) is statically enabled
        // by this cfg; the loads read 16 bytes from `table` and 64
        // bytes from `self.0`, and the store writes 64 bytes into the
        // local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let t128 = _mm_loadu_si128(table.as_ptr() as *const __m128i);
            let t = _mm512_broadcast_i32x4(t128);
            let i = _mm512_loadu_si512(self.0.as_ptr() as *const __m512i);
            let r = _mm512_shuffle_epi8(t, i);
            let mut out = [0u8; 64];
            _mm512_storeu_si512(out.as_mut_ptr() as *mut __m512i, r);
            return U8x64(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 64];
            for i in 0..64 {
                v[i] = table[(self.0[i] & 0x0F) as usize];
            }
            U8x64(v)
        }
    }

    /// `vpermt2b`-style two-source 64-lane permute (the AVX-512VBMI
    /// primitive the Clausecker–Lemire transcoder builds its compress
    /// steps from): lane `i` of the result is
    /// `concat(self, rhs)[idx[i] & 0x7F]`, or zero when `idx[i] & 0x80`
    /// is set (the `pshufb` zeroing convention, realized as a
    /// zero-masked `_mm512_maskz_permutex2var_epi8`).
    #[inline]
    pub fn permute2(self, rhs: U8x64, idx: U8x64) -> U8x64 {
        #[cfg(all(
            target_arch = "x86_64",
            target_feature = "avx512bw",
            target_feature = "avx512vbmi"
        ))]
        // SAFETY: avx512bw + avx512vbmi are statically enabled by this
        // cfg; the loads read 64 bytes each from `self.0`/`rhs.0`/
        // `idx.0` (`[u8; 64]`) and the store writes 64 bytes into the
        // local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm512_loadu_si512(self.0.as_ptr() as *const __m512i);
            let b = _mm512_loadu_si512(rhs.0.as_ptr() as *const __m512i);
            let ix = _mm512_loadu_si512(idx.0.as_ptr() as *const __m512i);
            // Zero the lanes whose index has the high bit set.
            let keep = !_mm512_movepi8_mask(ix);
            let r = _mm512_maskz_permutex2var_epi8(keep, a, ix, b);
            let mut out = [0u8; 64];
            _mm512_storeu_si512(out.as_mut_ptr() as *mut __m512i, r);
            return U8x64(out);
        }
        #[allow(unreachable_code)]
        {
            let mut cat = [0u8; 128];
            cat[..64].copy_from_slice(&self.0);
            cat[64..].copy_from_slice(&rhs.0);
            let mut v = [0u8; 64];
            for i in 0..64 {
                let j = idx.0[i];
                v[i] = if j & 0x80 != 0 { 0 } else { cat[(j & 0x7F) as usize] };
            }
            U8x64(v)
        }
    }

    /// Cross-register lag: lane `i` is the byte `N` positions before
    /// lane `i` in the concatenated stream `prev_block ++ self`. Unlike
    /// [`U8x64::shuffle`], this crosses the 128-bit quarters — realized
    /// as one [`U8x64::permute2`] with the constant index
    /// `64 - N + i` (the AVX-512VBMI idiom; on AVX2 this takes a
    /// permute *and* an align per register).
    #[inline]
    pub fn prev<const N: usize>(self, prev_block: U8x64) -> U8x64 {
        debug_assert!(N >= 1 && N <= 3);
        let mut idx = [0u8; 64];
        let mut i = 0;
        while i < 64 {
            idx[i] = (64 - N + i) as u8;
            i += 1;
        }
        prev_block.permute2(self, U8x64(idx))
    }

    /// Byte interleave, low half, **sequential** across the register
    /// (the [`SimdBytes::interleave_lo`] convention): result lane `2i`
    /// is `self[i]`, lane `2i + 1` is `rhs[i]`, for `i < 32`. Loop form
    /// only — LLVM synthesizes the two-source shuffle, and the
    /// sequential semantics are deliberately *not* `vpunpcklbw` (which
    /// interleaves per 128-bit quarter).
    #[inline]
    pub fn interleave_lo(self, rhs: U8x64) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..32 {
            v[2 * i] = self.0[i];
            v[2 * i + 1] = rhs.0[i];
        }
        U8x64(v)
    }

    /// Byte interleave, high half (sequential — see
    /// [`U8x64::interleave_lo`]): result lane `2i` is `self[32 + i]`.
    #[inline]
    pub fn interleave_hi(self, rhs: U8x64) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..32 {
            v[2 * i] = self.0[32 + i];
            v[2 * i + 1] = rhs.0[32 + i];
        }
        U8x64(v)
    }

    /// True iff any lane is non-zero.
    #[inline]
    pub fn any(self) -> bool {
        let mut acc = 0u8;
        for i in 0..64 {
            acc |= self.0[i];
        }
        acc != 0
    }

    /// OR-reduction of all lanes.
    #[inline]
    pub fn reduce_or(self) -> u8 {
        let mut acc = 0u8;
        for i in 0..64 {
            acc |= self.0[i];
        }
        acc
    }

    /// True iff every lane is ASCII (MSB clear).
    #[inline]
    pub fn is_ascii(self) -> bool {
        self.reduce_or() < 0x80
    }
}

impl SimdBytes for U8x64 {
    const LANES: usize = 64;

    #[inline]
    fn zero() -> Self {
        U8x64::ZERO
    }
    #[inline]
    fn load(src: &[u8]) -> Self {
        U8x64::load(src)
    }
    #[inline]
    fn store(self, dst: &mut [u8]) {
        U8x64::store(self, dst)
    }
    #[inline]
    fn splat(b: u8) -> Self {
        U8x64::splat(b)
    }
    #[inline]
    fn from_fn(mut f: impl FnMut(usize) -> u8) -> Self {
        let mut v = [0u8; 64];
        for (i, lane) in v.iter_mut().enumerate() {
            *lane = f(i);
        }
        U8x64(v)
    }
    #[inline]
    fn and(self, rhs: Self) -> Self {
        U8x64::and(self, rhs)
    }
    #[inline]
    fn or(self, rhs: Self) -> Self {
        U8x64::or(self, rhs)
    }
    #[inline]
    fn xor(self, rhs: Self) -> Self {
        U8x64::xor(self, rhs)
    }
    #[inline]
    fn saturating_sub(self, rhs: Self) -> Self {
        U8x64::saturating_sub(self, rhs)
    }
    #[inline]
    fn shr<const N: u32>(self) -> Self {
        U8x64::shr::<N>(self)
    }
    #[inline]
    fn movemask(self) -> u64 {
        U8x64::movemask(self)
    }
    #[inline]
    fn shuffle(self, idx: Self) -> Self {
        U8x64::shuffle(self, idx)
    }
    #[inline]
    fn lookup16(self, table: &[u8; 16]) -> Self {
        U8x64::lookup16(self, table)
    }
    #[inline]
    fn prev<const N: usize>(self, prev_block: Self) -> Self {
        U8x64::prev::<N>(self, prev_block)
    }
    #[inline]
    fn interleave_lo(self, rhs: Self) -> Self {
        U8x64::interleave_lo(self, rhs)
    }
    #[inline]
    fn interleave_hi(self, rhs: Self) -> Self {
        U8x64::interleave_hi(self, rhs)
    }
    #[inline]
    fn any(self) -> bool {
        U8x64::any(self)
    }
    #[inline]
    fn is_ascii(self) -> bool {
        U8x64::is_ascii(self)
    }
    #[inline]
    fn load_partial(src: &[u8]) -> Self {
        U8x64::load_partial(src)
    }
    #[inline]
    fn store_partial(self, dst: &mut [u8]) {
        U8x64::store_partial(self, dst)
    }

    #[inline]
    fn kl_step(
        self,
        prev_block: Self,
        prev_incomplete: Self,
        error_acc: Self,
        t1h: &[u8; 16],
        t1l: &[u8; 16],
        t2h: &[u8; 16],
    ) -> (Self, Self) {
        // The per-op AVX-512 intrinsics (prev via permute2, lookup16 via
        // broadcast + vpshufb) keep the portable formulation
        // register-resident; no fused path needed.
        kl_step_portable(self, prev_block, prev_incomplete, error_acc, t1h, t1l, t2h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_per_quarter_vpshufb() {
        let v = U8x64::from_fn(|i| 100u8.wrapping_add(i as u8));
        // Reverse within each quarter.
        let idx = U8x64::from_fn(|i| (15 - (i & 0x0F)) as u8);
        let out = v.shuffle(idx);
        for i in 0..64 {
            let quarter = i & 0x30;
            let expected = 100u8.wrapping_add((quarter + (15 - (i & 0x0F))) as u8);
            assert_eq!(out.0[i], expected, "lane {i}");
        }
        // High bit zeroes.
        assert_eq!(v.shuffle(U8x64::splat(0x80)), U8x64::ZERO);
    }

    #[test]
    fn lookup16_broadcasts_the_table() {
        let table: [u8; 16] = core::array::from_fn(|i| (i * 5) as u8);
        let idx = U8x64::from_fn(|i| (i % 16) as u8);
        let out = idx.lookup16(&table);
        for i in 0..64 {
            assert_eq!(out.0[i], table[i % 16], "lane {i}");
        }
    }

    #[test]
    fn prev_crosses_every_quarter_boundary() {
        let prev = U8x64::from_fn(|i| i as u8);
        let cur = U8x64::from_fn(|i| 64 + i as u8);
        for (n, got) in
            [(1usize, cur.prev::<1>(prev)), (2, cur.prev::<2>(prev)), (3, cur.prev::<3>(prev))]
        {
            for i in 0..64 {
                let expected = (64 + i - n) as u8;
                assert_eq!(got.0[i], expected, "N={n} lane {i}");
            }
        }
    }

    #[test]
    fn permute2_selects_across_both_sources_and_zeroes() {
        let a = U8x64::from_fn(|i| i as u8);
        let b = U8x64::from_fn(|i| 64 + i as u8);
        // Even lanes from `b` reversed, odd lanes zeroed.
        let idx = U8x64::from_fn(|i| {
            if i % 2 == 0 {
                (64 + (63 - i)) as u8
            } else {
                0x80
            }
        });
        let out = a.permute2(b, idx);
        for i in 0..64 {
            let expected = if i % 2 == 0 { (64 + (63 - i)) as u8 } else { 0 };
            assert_eq!(out.0[i], expected, "lane {i}");
        }
    }

    #[test]
    fn movemask_matches_definition() {
        let v = U8x64::from_fn(|i| if i % 5 == 0 { 0x80 } else { 0x7F });
        let m = v.movemask();
        for i in 0..64 {
            assert_eq!((m >> i) & 1 == 1, i % 5 == 0, "bit {i}");
        }
        assert_eq!(U8x64::splat(0xFF).movemask(), u64::MAX);
        assert_eq!(U8x64::ZERO.movemask(), 0);
    }

    #[test]
    fn partial_load_store_match_the_copy_semantics() {
        let src: Vec<u8> = (0..64u8).map(|i| i.wrapping_mul(37).wrapping_add(1)).collect();
        for n in [0usize, 1, 7, 15, 16, 31, 32, 33, 63, 64] {
            let v = U8x64::load_partial(&src[..n]);
            for i in 0..64 {
                let expected = if i < n { src[i] } else { 0 };
                assert_eq!(v.0[i], expected, "load n={n} lane {i}");
            }
            let full = U8x64::load(&src);
            let mut out = vec![0xAAu8; n];
            full.store_partial(&mut out);
            assert_eq!(&out[..], &src[..n], "store n={n}");
        }
    }

    #[test]
    fn interleave_is_sequential_not_per_quarter() {
        let a = U8x64::from_fn(|i| i as u8);
        let b = U8x64::from_fn(|i| 100u8.wrapping_add(i as u8));
        let lo = a.interleave_lo(b);
        let hi = a.interleave_hi(b);
        for i in 0..32 {
            assert_eq!(lo.0[2 * i], i as u8, "lo lane {i}");
            assert_eq!(lo.0[2 * i + 1], 100u8.wrapping_add(i as u8), "lo lane {i}");
            assert_eq!(hi.0[2 * i], 32 + i as u8, "hi lane {i}");
            assert_eq!(hi.0[2 * i + 1], 100u8.wrapping_add(32 + i as u8), "hi lane {i}");
        }
    }

    #[test]
    fn halves_and_quarters_round_trip() {
        let v = U8x64::from_fn(|i| i as u8);
        let (lo, hi) = v.to_halves();
        assert_eq!(lo.0[0], 0);
        assert_eq!(lo.0[31], 31);
        assert_eq!(hi.0[0], 32);
        assert_eq!(hi.0[31], 63);
        let q = v.to_quarters();
        for (qi, quarter) in q.iter().enumerate() {
            for i in 0..16 {
                assert_eq!(quarter.0[i], (16 * qi + i) as u8, "quarter {qi} lane {i}");
            }
        }
    }
}
