//! 32-lane 16-bit vector (the 512-bit UTF-16 side).

use super::backend::SimdWords;
use super::U8x64;

/// A 32-lane vector of 16-bit code units. Loop-based; every operation
/// autovectorizes to AVX-512BW at `opt-level=3` when compiled for a CPU
/// that has it, and stays correct scalar code elsewhere. `movemask`
/// carries the explicit `vpmovw2m` path (the one operation LLVM does
/// not reliably synthesize from the shift-or loop) — at 32 lanes the
/// mask exactly fills the `u32` the [`SimdWords`] trait already speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(transparent)]
pub struct U16x32(pub [u16; 32]);

impl U16x32 {
    /// The all-zero vector.
    pub const ZERO: U16x32 = U16x32([0; 32]);

    /// Load 32 little-endian 16-bit words from 64 bytes.
    #[inline]
    pub fn load_le_bytes(src: &[u8]) -> U16x32 {
        let mut v = [0u16; 32];
        for i in 0..32 {
            v[i] = u16::from_le_bytes([src[2 * i], src[2 * i + 1]]);
        }
        U16x32(v)
    }

    /// Load 32 words from a `&[u16]` slice (length >= 32).
    #[inline]
    pub fn load(src: &[u16]) -> U16x32 {
        let mut v = [0u16; 32];
        v.copy_from_slice(&src[..32]);
        U16x32(v)
    }

    /// Broadcast one word to all lanes.
    #[inline]
    pub fn splat(w: u16) -> U16x32 {
        U16x32([w; 32])
    }

    /// Store all lanes to the front of `dst` (`dst.len() >= 32`).
    #[inline]
    pub fn store(self, dst: &mut [u16]) {
        dst[..32].copy_from_slice(&self.0);
    }

    /// Reinterpret as 64 bytes (little-endian lane order).
    #[inline]
    pub fn to_bytes(self) -> U8x64 {
        let mut v = [0u8; 64];
        for i in 0..32 {
            let [lo, hi] = self.0[i].to_le_bytes();
            v[2 * i] = lo;
            v[2 * i + 1] = hi;
        }
        U8x64(v)
    }

    /// Lane-wise bitwise AND.
    #[inline]
    pub fn and(self, rhs: U16x32) -> U16x32 {
        let mut v = [0u16; 32];
        for i in 0..32 {
            v[i] = self.0[i] & rhs.0[i];
        }
        U16x32(v)
    }

    /// Lane-wise bitwise OR.
    #[inline]
    pub fn or(self, rhs: U16x32) -> U16x32 {
        let mut v = [0u16; 32];
        for i in 0..32 {
            v[i] = self.0[i] | rhs.0[i];
        }
        U16x32(v)
    }

    /// Lane-wise bitwise NOT.
    #[inline]
    pub fn not(self) -> U16x32 {
        let mut v = [0u16; 32];
        for i in 0..32 {
            v[i] = !self.0[i];
        }
        U16x32(v)
    }

    /// Lane-wise logical shift right by a constant (`vpsrlw`).
    #[inline]
    pub fn shr<const N: u32>(self) -> U16x32 {
        let mut v = [0u16; 32];
        for i in 0..32 {
            v[i] = self.0[i] >> N;
        }
        U16x32(v)
    }

    /// Lane-wise shift left by a constant (`vpsllw`).
    #[inline]
    pub fn shl<const N: u32>(self) -> U16x32 {
        let mut v = [0u16; 32];
        for i in 0..32 {
            v[i] = self.0[i] << N;
        }
        U16x32(v)
    }

    /// Lane-wise unsigned less-than mask: `0xFFFF` where `self < rhs`.
    #[inline]
    pub fn lt_mask(self, rhs: U16x32) -> U16x32 {
        let mut v = [0u16; 32];
        for i in 0..32 {
            v[i] = if self.0[i] < rhs.0[i] { 0xFFFF } else { 0 };
        }
        U16x32(v)
    }

    /// 32-bit mask: bit `i` = MSB of lane `i` (`vpmovw2m`).
    #[inline]
    pub fn movemask(self) -> u32 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512bw"))]
        // SAFETY: avx512bw is statically enabled by this cfg; the load
        // reads exactly 64 bytes from `self.0`, a `[u16; 32]`.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm512_loadu_si512(self.0.as_ptr() as *const __m512i);
            return _mm512_movepi16_mask(a);
        }
        #[allow(unreachable_code)]
        {
            let mut m = 0u32;
            for i in 0..32 {
                m |= ((self.0[i] >> 15) as u32) << i;
            }
            m
        }
    }

    /// OR-reduction of all lanes.
    #[inline]
    pub fn reduce_or(self) -> u16 {
        let mut acc = 0u16;
        for i in 0..32 {
            acc |= self.0[i];
        }
        acc
    }

    /// True iff any word is in the surrogate range `0xD800..=0xDFFF`.
    #[inline]
    pub fn has_surrogate(self) -> bool {
        let mut any = false;
        for i in 0..32 {
            any |= (self.0[i] & 0xF800) == 0xD800;
        }
        any
    }
}

impl SimdWords for U16x32 {
    const LANES: usize = 32;
    type Bytes = U8x64;

    #[inline]
    fn load(src: &[u16]) -> Self {
        U16x32::load(src)
    }
    #[inline]
    fn load_le_bytes(src: &[u8]) -> Self {
        U16x32::load_le_bytes(src)
    }
    #[inline]
    fn splat(w: u16) -> Self {
        U16x32::splat(w)
    }
    #[inline]
    fn store(self, dst: &mut [u16]) {
        U16x32::store(self, dst)
    }
    #[inline]
    fn to_bytes(self) -> U8x64 {
        U16x32::to_bytes(self)
    }
    #[inline]
    fn and(self, rhs: Self) -> Self {
        U16x32::and(self, rhs)
    }
    #[inline]
    fn or(self, rhs: Self) -> Self {
        U16x32::or(self, rhs)
    }
    #[inline]
    fn not(self) -> Self {
        U16x32::not(self)
    }
    #[inline]
    fn shr<const N: u32>(self) -> Self {
        U16x32::shr::<N>(self)
    }
    #[inline]
    fn shl<const N: u32>(self) -> Self {
        U16x32::shl::<N>(self)
    }
    #[inline]
    fn lt_mask(self, rhs: Self) -> Self {
        U16x32::lt_mask(self, rhs)
    }
    #[inline]
    fn movemask(self) -> u32 {
        U16x32::movemask(self)
    }
    #[inline]
    fn reduce_or(self) -> u16 {
        U16x32::reduce_or(self)
    }
    #[inline]
    fn has_surrogate(self) -> bool {
        U16x32::has_surrogate(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn le_byte_roundtrip() {
        let bytes: Vec<u8> = (0..64).collect();
        let v = U16x32::load_le_bytes(&bytes);
        assert_eq!(v.0[0], 0x0100);
        assert_eq!(v.0[31], 0x3F3E);
        assert_eq!(v.to_bytes().0.to_vec(), bytes);
    }

    #[test]
    fn movemask_fills_the_full_u32() {
        let mut w = [0u16; 32];
        w[1] = 0x8000;
        w[17] = 0xFFFF;
        w[31] = 0x8001;
        assert_eq!(U16x32(w).movemask(), (1 << 1) | (1 << 17) | (1u32 << 31));
        assert_eq!(U16x32::splat(0xFFFF).movemask(), u32::MAX);
        assert_eq!(U16x32::ZERO.movemask(), 0);
    }

    #[test]
    fn surrogate_detection() {
        let mut w = [0x41u16; 32];
        assert!(!U16x32(w).has_surrogate());
        w[30] = 0xD800;
        assert!(U16x32(w).has_surrogate());
        assert!(!U16x32([0xD7FF; 32]).has_surrogate());
        assert!(U16x32([0xDFFF; 32]).has_surrogate());
    }
}
