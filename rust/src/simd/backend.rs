//! Width-generic SIMD backend layer.
//!
//! The paper expresses both transcoders and the Keiser–Lemire validator
//! in terms of a small primitive set — loads/stores, splats, lane-wise
//! logic and arithmetic, `movemask`, `pshufb`-style shuffles, nibble
//! table lookups and the `palignr`-style `prev` lag — and retargets that
//! set per instruction set (§6.1). This module captures the primitive
//! set as traits so the kernels can be written once and instantiated at
//! any register width:
//!
//! * [`SimdBytes`] — a vector of `u8` lanes (the UTF-8 side).
//! * [`SimdWords`] — a vector of `u16` lanes (the UTF-16 side).
//! * [`VectorBackend`] — ties a byte vector and a word vector of the
//!   same width together and names the backend ([`V128`], [`V256`]).
//!
//! `V128` is backed by the original [`U8x16`]/[`U16x8`] types (with
//! their SSSE3 intrinsic paths on x64 and NEON paths on aarch64);
//! `V256` by [`U8x32`]/[`U16x16`] (loop-based, with AVX2 intrinsic
//! paths for the operations LLVM cannot synthesize from loops:
//! `shuffle`, `lookup16`, `prev`, `movemask`); [`V512`] by
//! [`U8x64`]/[`U16x32`] (loop-based, with AVX-512BW/VBMI paths:
//! `vpmovb2m` movemask, `vpermt2b` two-source permute for `prev`, and
//! masked loads/stores for exact tails). [`best_key`] picks the widest
//! backend the running CPU supports, which is how the `best`
//! engine-registry alias dispatches.
//!
//! ### Wide shuffle semantics
//!
//! [`SimdBytes::shuffle`] and [`SimdBytes::lookup16`] follow the
//! `vpshufb` convention at every width: the shuffle is **per 16-byte
//! group** (lane `i` selects from its own half at 32 lanes, its own
//! quarter at 64, via `idx[i] & 0x0F`). Nibble lookups are unaffected
//! (the 16-byte table is logically broadcast to every group); code that
//! needs a true cross-group permute uses [`super::shuffle32`]
//! (two-source, 16-byte result) or [`U8x64::permute2`] (two-source, 64
//! lanes) explicitly.

use super::{U16x16, U16x32, U16x8, U8x16, U8x32, U8x64};

/// A vector of `u8` lanes exposing the paper's primitive set.
///
/// Semantics match the x64 instructions named on each method; the
/// loop-based implementations are bit-exact with the intrinsic paths
/// (asserted by the `simd` unit tests).
pub trait SimdBytes: Copy + Send + Sync + std::fmt::Debug + 'static {
    /// Number of 8-bit lanes (16, 32 or 64).
    const LANES: usize;

    /// The all-zero vector.
    fn zero() -> Self;
    /// Load `LANES` bytes from the front of `src` (`src.len() >= LANES`).
    fn load(src: &[u8]) -> Self;
    /// Store `LANES` bytes to the front of `dst` (`dst.len() >= LANES`).
    fn store(self, dst: &mut [u8]);
    /// Broadcast one byte to all lanes.
    fn splat(b: u8) -> Self;
    /// Build a vector lane-by-lane (table/constant construction only —
    /// not a hot-path operation).
    fn from_fn(f: impl FnMut(usize) -> u8) -> Self;

    /// Lane-wise bitwise AND.
    fn and(self, rhs: Self) -> Self;
    /// Lane-wise bitwise OR.
    fn or(self, rhs: Self) -> Self;
    /// Lane-wise bitwise XOR.
    fn xor(self, rhs: Self) -> Self;
    /// Lane-wise unsigned saturating subtraction (`psubusb`).
    fn saturating_sub(self, rhs: Self) -> Self;
    /// Lane-wise logical shift right by a constant.
    fn shr<const N: u32>(self) -> Self;

    /// `pmovmskb`: bit `i` of the result is the MSB of lane `i`.
    fn movemask(self) -> u64;
    /// Byte interleave, low half (`punpcklbw`-style, but **sequential**
    /// across the whole register at every width): lane `2i` of the
    /// result is `self[i]`, lane `2i + 1` is `rhs[i]`, for
    /// `i < LANES / 2`. The Latin-1 expansion kernel pairs lead bytes
    /// with payload bytes this way before its compaction shuffle.
    fn interleave_lo(self, rhs: Self) -> Self;
    /// Byte interleave, high half: like [`SimdBytes::interleave_lo`]
    /// for `i >= LANES / 2` (lane `2i` of the result is
    /// `self[LANES / 2 + i]`).
    fn interleave_hi(self, rhs: Self) -> Self;
    /// `pshufb` (per 16-byte half at 32 lanes — see the module docs).
    fn shuffle(self, idx: Self) -> Self;
    /// Nibble-table lookup: every lane must be in `[0, 16)`; the 16-byte
    /// table is broadcast across halves at 32 lanes.
    fn lookup16(self, table: &[u8; 16]) -> Self;
    /// `palignr`-style lag: lane `i` of the result is the byte `N`
    /// positions before lane `i` in the stream `prev_block ++ self`.
    fn prev<const N: usize>(self, prev_block: Self) -> Self;

    /// True iff any lane is non-zero.
    fn any(self) -> bool;
    /// True iff every lane is ASCII (MSB clear).
    fn is_ascii(self) -> bool;

    /// Load `src.len()` bytes (must be `<= LANES`) into the low lanes,
    /// zero-filling the rest — the masked-tail load. The default is a
    /// zero-padded copy through a stack buffer; [`U8x64`] overrides it
    /// with one AVX-512BW masked load (`vmovdqu8 {k}{z}`). Zero padding
    /// is ASCII, so validators can feed the result directly.
    #[inline]
    fn load_partial(src: &[u8]) -> Self {
        debug_assert!(src.len() <= Self::LANES);
        let mut buf = [0u8; 64]; // covers every backend width
        buf[..src.len()].copy_from_slice(src);
        Self::load(&buf)
    }

    /// Store the low `dst.len().min(LANES)` lanes — the masked-tail
    /// store, which never writes past `dst`. The default copies through
    /// a stack buffer; [`U8x64`] overrides it with one AVX-512BW masked
    /// store (`vmovdqu8 {k}`).
    #[inline]
    fn store_partial(self, dst: &mut [u8]) {
        let n = dst.len().min(Self::LANES);
        let mut buf = [0u8; 64];
        self.store(&mut buf);
        dst[..n].copy_from_slice(&buf[..n]);
    }

    /// Unsigned `>=` threshold mask: bit `i` of the result is set iff
    /// lane `i` is `>= t`, for thresholds in the non-ASCII range
    /// (`t >= 0x80`). One `psubusb` + `pmovmskb`: `x - (t - 0x80)`
    /// saturates to a value with the MSB set exactly when `x >= t`.
    /// The counting kernels ([`crate::count`]) classify lead and
    /// continuation bytes with this.
    #[inline]
    fn ge_mask(self, t: u8) -> u64 {
        debug_assert!(t >= 0x80, "ge_mask is defined for thresholds >= 0x80");
        self.saturating_sub(Self::splat(t - 0x80)).movemask()
    }

    /// Per-lane maxima for the Keiser–Lemire incomplete-at-end check: a
    /// register is complete unless its last three bytes start a longer
    /// sequence.
    fn incomplete_max() -> Self {
        Self::from_fn(|i| match Self::LANES - 1 - i {
            0 => 0xC0 - 1,
            1 => 0xE0 - 1,
            2 => 0xF0 - 1,
            _ => 0xFF,
        })
    }

    /// One Keiser–Lemire validation step over this register.
    ///
    /// Given the previous register and the carried incompleteness mask,
    /// returns `(new_error_accumulator, new_incomplete_mask)`. The
    /// default is the portable trait-op formulation; `U8x16` overrides
    /// it with a fused SSSE3 implementation where available.
    #[inline]
    fn kl_step(
        self,
        prev_block: Self,
        prev_incomplete: Self,
        error_acc: Self,
        t1h: &[u8; 16],
        t1l: &[u8; 16],
        t2h: &[u8; 16],
    ) -> (Self, Self) {
        kl_step_portable(self, prev_block, prev_incomplete, error_acc, t1h, t1l, t2h)
    }
}

/// Portable Keiser–Lemire step shared by the trait default and the
/// non-x86 fallbacks of the specialized implementations.
#[inline]
pub(crate) fn kl_step_portable<V: SimdBytes>(
    input: V,
    prev_block: V,
    prev_incomplete: V,
    error_acc: V,
    t1h: &[u8; 16],
    t1l: &[u8; 16],
    t2h: &[u8; 16],
) -> (V, V) {
    let error = if input.is_ascii() {
        // An ASCII register cannot complete a pending multi-byte
        // sequence: surface any carried incompleteness.
        error_acc.or(prev_incomplete)
    } else {
        let prev1 = input.prev::<1>(prev_block);
        // Three nibble classifications ANDed together (the special-case
        // bitmap of the Keiser–Lemire validator).
        let sc = prev1
            .shr::<4>()
            .lookup16(t1h)
            .and(prev1.and(V::splat(0x0F)).lookup16(t1l))
            .and(input.shr::<4>().lookup16(t2h));
        // Where a byte *must* be the 2nd/3rd continuation of a 3/4-byte
        // sequence its TWO_CONTS bit (0x80) is expected; anywhere else
        // that bit is an error — computed as an XOR.
        let prev2 = input.prev::<2>(prev_block);
        let prev3 = input.prev::<3>(prev_block);
        let is_third = prev2.saturating_sub(V::splat(0xE0 - 0x80));
        let is_fourth = prev3.saturating_sub(V::splat(0xF0 - 0x80));
        let must32_80 = is_third.or(is_fourth).and(V::splat(0x80));
        error_acc.or(must32_80.xor(sc))
    };
    (error, input.saturating_sub(V::incomplete_max()))
}

/// A vector of `u16` lanes (the UTF-16 side of the transcoders).
pub trait SimdWords: Copy + Send + Sync + std::fmt::Debug + 'static {
    /// Number of 16-bit lanes (8, 16 or 32).
    const LANES: usize;
    /// The byte vector of the same total width.
    type Bytes: SimdBytes;

    /// Load `LANES` words from a `&[u16]` slice (`src.len() >= LANES`).
    fn load(src: &[u16]) -> Self;
    /// Load `LANES` little-endian words from `2 * LANES` bytes.
    fn load_le_bytes(src: &[u8]) -> Self;
    /// Broadcast one word to all lanes.
    fn splat(w: u16) -> Self;
    /// Store `LANES` words to the front of `dst` (`dst.len() >= LANES`).
    fn store(self, dst: &mut [u16]);
    /// Reinterpret as bytes (little-endian lane order).
    fn to_bytes(self) -> Self::Bytes;

    /// Lane-wise bitwise AND.
    fn and(self, rhs: Self) -> Self;
    /// Lane-wise bitwise OR.
    fn or(self, rhs: Self) -> Self;
    /// Lane-wise bitwise NOT.
    fn not(self) -> Self;
    /// Lane-wise logical shift right by a constant.
    fn shr<const N: u32>(self) -> Self;
    /// Lane-wise shift left by a constant.
    fn shl<const N: u32>(self) -> Self;
    /// Lane-wise unsigned less-than mask: `0xFFFF` where `self < rhs`.
    fn lt_mask(self, rhs: Self) -> Self;
    /// Bit `i` of the result is the MSB of lane `i`.
    fn movemask(self) -> u32;
    /// OR-reduction of all lanes.
    fn reduce_or(self) -> u16;
    /// True iff any word is in the surrogate range `0xD800..=0xDFFF`.
    fn has_surrogate(self) -> bool;
}

/// A named register width: a byte vector and a word vector of the same
/// total width, plus the identifiers the engine registry uses.
pub trait VectorBackend:
    Copy + Clone + Default + Send + Sync + std::fmt::Debug + 'static
{
    /// Vector width in bytes (== `Bytes::LANES` == `2 * Words::LANES`).
    const WIDTH: usize;
    /// Engine-registry key (`"simd128"` / `"simd256"` / `"simd512"`).
    const KEY: &'static str;
    /// Display name used by engines on this backend.
    const ENGINE_NAME: &'static str;

    /// The byte-lane vector of this width.
    type Bytes: SimdBytes;
    /// The word-lane vector of this width.
    type Words: SimdWords<Bytes = Self::Bytes>;
}

/// The 128-bit backend: the paper's SSE/NEON-width formulation, backed
/// by [`U8x16`]/[`U16x8`] with their SSSE3 intrinsic paths.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct V128;

impl VectorBackend for V128 {
    const WIDTH: usize = 16;
    const KEY: &'static str = "simd128";
    const ENGINE_NAME: &'static str = "ours";
    type Bytes = U8x16;
    type Words = U16x8;
}

/// The 256-bit backend: 32-lane vectors, loop-based with AVX2 intrinsic
/// paths for `shuffle`/`lookup16`/`prev`/`movemask`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct V256;

impl VectorBackend for V256 {
    const WIDTH: usize = 32;
    const KEY: &'static str = "simd256";
    const ENGINE_NAME: &'static str = "ours-256";
    type Bytes = U8x32;
    type Words = U16x16;
}

/// The 512-bit backend: 64-lane vectors, loop-based with AVX-512BW/VBMI
/// intrinsic paths (`vpmovb2m` movemask, `vpshufb`-per-quarter shuffle,
/// `vpermt2b` two-source permute behind `prev`, masked tail
/// loads/stores).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct V512;

impl VectorBackend for V512 {
    const WIDTH: usize = 64;
    const KEY: &'static str = "simd512";
    const ENGINE_NAME: &'static str = "ours-512";
    type Bytes = U8x64;
    type Words = U16x32;
}

/// Registry key of the widest backend that is *worth running* here —
/// what the `best` registry alias resolves to at process start.
///
/// Two conditions must both hold for a wide backend to win, and they
/// are different in kind:
///
/// * **compile-time**: the build enabled the matching codegen
///   (`-C target-cpu=native`, or `target-feature=+avx2` /
///   `+avx512bw`), so the `U8x32`/`U8x64` intrinsic paths actually
///   exist. In a portable build the wide backends are correct but
///   loop-based — typically no faster than the tuned 128-bit engine —
///   so `best` stays on `simd128` there.
/// * **runtime**: the CPU reports the feature, so those compiled paths
///   can execute.
///
/// The ladder is `simd512` (AVX-512BW compiled in *and* detected),
/// then `simd256` (AVX2 compiled in and detected), then `simd128`.
/// Every key remains individually selectable in every build for A/B
/// measurement regardless of what `best` picks.
pub fn best_key() -> &'static str {
    // Under Miri there is no host CPU to probe and the intrinsic paths
    // are not meaningfully "usable": pin `best` to the portable 128-bit
    // engine so interpreted runs are deterministic regardless of the
    // RUSTFLAGS the build happened to carry.
    #[cfg(not(miri))]
    {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512bw"))]
        {
            if std::arch::is_x86_feature_detected!("avx512bw") {
                return V512::KEY;
            }
        }
        #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return V256::KEY;
            }
        }
    }
    V128::KEY
}

/// Width in bytes of the backend [`best_key`] names.
pub fn best_width() -> usize {
    match best_key() {
        k if k == V512::KEY => V512::WIDTH,
        k if k == V256::KEY => V256::WIDTH,
        _ => V128::WIDTH,
    }
}

/// Short name of the instruction set the selected [`best_key`] backend
/// actually runs on — what the bench-json schema v6 `backend` field
/// reports, so a perf trajectory row names the ISA it measured.
///
/// Unlike [`best_key`] (a registry key), this names hardware: e.g. a
/// portable x64 build reports `"x86-64-portable"` even though `best`
/// resolves to `simd128`, because the SSSE3 paths are not compiled in.
pub fn detected_isa() -> &'static str {
    // Interpreted runs execute no intrinsics and cannot probe the host
    // CPU; name them explicitly so a bench record produced under Miri
    // can never be mistaken for a hardware measurement.
    #[cfg(miri)]
    {
        return "miri";
    }
    #[cfg(all(not(miri), target_arch = "x86_64"))]
    {
        #[cfg(all(target_feature = "avx512bw", target_feature = "avx512vbmi"))]
        if std::arch::is_x86_feature_detected!("avx512vbmi") {
            return "avx512bw+vbmi";
        }
        #[cfg(target_feature = "avx512bw")]
        if std::arch::is_x86_feature_detected!("avx512bw") {
            return "avx512bw";
        }
        #[cfg(target_feature = "avx2")]
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
        #[cfg(target_feature = "ssse3")]
        if std::arch::is_x86_feature_detected!("ssse3") {
            return "ssse3";
        }
        return "x86-64-portable";
    }
    #[cfg(all(not(miri), target_arch = "aarch64"))]
    {
        // NEON is baseline on aarch64; the intrinsic paths are always
        // compiled in there.
        return "neon";
    }
    #[cfg(all(not(miri), not(any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        return "portable";
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incomplete_max_matches_hand_written_constant() {
        let m16 = <U8x16 as SimdBytes>::incomplete_max();
        let mut expected = [0xFFu8; 16];
        expected[13] = 0xF0 - 1;
        expected[14] = 0xE0 - 1;
        expected[15] = 0xC0 - 1;
        assert_eq!(m16.0, expected);
        let m32 = <U8x32 as SimdBytes>::incomplete_max();
        assert_eq!(m32.0[28], 0xFF);
        assert_eq!(m32.0[29], 0xF0 - 1);
        assert_eq!(m32.0[30], 0xE0 - 1);
        assert_eq!(m32.0[31], 0xC0 - 1);
        let m64 = <U8x64 as SimdBytes>::incomplete_max();
        assert_eq!(m64.0[60], 0xFF);
        assert_eq!(m64.0[61], 0xF0 - 1);
        assert_eq!(m64.0[62], 0xE0 - 1);
        assert_eq!(m64.0[63], 0xC0 - 1);
    }

    #[test]
    fn partial_defaults_match_overrides_at_every_width() {
        let src: Vec<u8> = (0..64u8).map(|i| i.wrapping_mul(41).wrapping_add(3)).collect();
        fn check<V: SimdBytes>(src: &[u8]) {
            for n in [0usize, 1, 7, 15, V::LANES / 2, V::LANES - 1, V::LANES] {
                let v = V::load_partial(&src[..n]);
                let mut out = [0u8; 64];
                v.store(&mut out);
                for i in 0..V::LANES {
                    let expected = if i < n { src[i] } else { 0 };
                    assert_eq!(out[i], expected, "lanes={} n={n} lane {i}", V::LANES);
                }
                let full = V::load(src);
                let mut short = vec![0xEEu8; n];
                full.store_partial(&mut short);
                assert_eq!(&short[..], &src[..n], "lanes={} n={n}", V::LANES);
            }
        }
        check::<U8x16>(&src);
        check::<U8x32>(&src);
        check::<U8x64>(&src);
    }

    #[test]
    fn ge_mask_matches_lane_comparison() {
        let mut bytes = [0u8; 32];
        for i in 0..32 {
            bytes[i] = (i as u8).wrapping_mul(37).wrapping_add(0x60);
        }
        for t in [0x80u8, 0xC0, 0xE0, 0xF0, 0xFF] {
            let m16 = U8x16(bytes[..16].try_into().unwrap()).ge_mask(t);
            let m32 = U8x32(bytes).ge_mask(t);
            for i in 0..16 {
                assert_eq!((m16 >> i) & 1 == 1, bytes[i] >= t, "t={t:#x} lane {i}");
            }
            for i in 0..32 {
                assert_eq!((m32 >> i) & 1 == 1, bytes[i] >= t, "t={t:#x} lane {i}");
            }
        }
    }

    #[test]
    fn best_key_names_a_registered_width() {
        assert!(["simd128", "simd256", "simd512"].contains(&best_key()));
        assert_eq!(best_width() == 32, best_key() == "simd256");
        assert_eq!(best_width() == 64, best_key() == "simd512");
        // The ISA name is always one of the known strings.
        assert!([
            "avx512bw+vbmi",
            "avx512bw",
            "avx2",
            "ssse3",
            "x86-64-portable",
            "neon",
            "portable"
        ]
        .contains(&detected_isa()));
    }

    #[test]
    fn width_constants_are_consistent() {
        assert_eq!(V128::WIDTH, <U8x16 as SimdBytes>::LANES);
        assert_eq!(V128::WIDTH, 2 * <U16x8 as SimdWords>::LANES);
        assert_eq!(V256::WIDTH, <U8x32 as SimdBytes>::LANES);
        assert_eq!(V256::WIDTH, 2 * <U16x16 as SimdWords>::LANES);
        assert_eq!(V512::WIDTH, <U8x64 as SimdBytes>::LANES);
        assert_eq!(V512::WIDTH, 2 * <U16x32 as SimdWords>::LANES);
    }
}
