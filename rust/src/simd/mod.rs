//! Portable, width-generic SIMD substrate.
//!
//! The paper's algorithms are expressed in terms of a handful of SIMD
//! primitives: vector loads, byte-wise comparisons, `movemask`-style
//! mask extraction, `pshufb`-style shuffles, nibble-table lookups and
//! the `palignr`-style `prev` lag. This module provides those
//! primitives at two register widths behind one trait surface:
//!
//! * **Backend layer** ([`backend`]) — the [`VectorBackend`] trait
//!   (with [`SimdBytes`] / [`SimdWords`] for the lane types) that the
//!   transcode kernels and the Keiser–Lemire validator are generic
//!   over, plus the two shipped backends:
//!   * [`V128`] — 16-byte vectors ([`U8x16`], [`U16x8`]), the paper's
//!     SSE/NEON-width formulation, with SSSE3 intrinsic paths on x64
//!     and NEON intrinsic paths on aarch64.
//!   * [`V256`] — 32-byte vectors ([`U8x32`], [`U16x16`]), loop-based
//!     with AVX2 intrinsic paths for the operations LLVM cannot
//!     synthesize from loops.
//!   * [`V512`] — 64-byte vectors ([`U8x64`], [`U16x32`]), loop-based
//!     with AVX-512BW/VBMI intrinsic paths (`vpmovb2m` movemask,
//!     `vpermt2b` two-source permute, masked tail loads/stores).
//! * **Value types** — fixed-width types implemented in safe,
//!   loop-based Rust. At `opt-level=3` the loops autovectorize into the
//!   corresponding machine SIMD on x64 (SSE/AVX2) and aarch64 (NEON);
//!   on other targets they remain correct scalar code — the same
//!   portability property the paper claims for its high-level C++
//!   approach (§6.1).
//!
//! The substrate intentionally mirrors the x64/NEON instruction
//! semantics that the paper relies on:
//!
//! * [`U8x16::shuffle`] is `pshufb`: an index with the high bit set
//!   produces a zero byte, otherwise the low 4 bits select a source
//!   lane. At 32 lanes [`U8x32::shuffle`] keeps the AVX2 `vpshufb`
//!   convention (per 16-byte half); [`shuffle32`] is the explicit
//!   two-source cross-half permute.
//! * [`U8x16::movemask`] is `pmovmskb`: one bit per lane, bit `i` = MSB
//!   of lane `i` (lane 0 → least-significant bit).
//! * [`U8x16::lookup16`] is the nibble-table lookup used by the
//!   Keiser–Lemire validator (a `pshufb` against a constant table).
//!
//! Which backend should a caller use? Usually none directly: the
//! engine registry's `best` alias resolves to the widest backend the
//! running CPU supports (see [`best_key`]), and `simd128` / `simd256` /
//! `simd512` name the widths explicitly.

pub mod backend;
mod u16x16;
mod u16x32;
mod u16x8;
mod u8x16;
mod u8x32;
mod u8x64;

pub use backend::{
    best_key, best_width, detected_isa, SimdBytes, SimdWords, VectorBackend, V128, V256, V512,
};
pub use u16x16::U16x16;
pub use u16x32::U16x32;
pub use u16x8::U16x8;
pub use u8x16::U8x16;
pub use u8x32::U8x32;
pub use u8x64::U8x64;

/// 32-lane byte permute (the POWER `vperm` / AVX2 two-source shuffle the
/// Inoue et al. transcoder relies on): lane `i` of the result is
/// `concat(lo, hi)[idx[i] & 0x1F]`, or zero when `idx[i] & 0x80` is set.
#[inline]
pub fn shuffle32(lo: U8x16, hi: U8x16, idx: U8x16) -> U8x16 {
    let mut cat = [0u8; 32];
    cat[..16].copy_from_slice(&lo.0);
    cat[16..].copy_from_slice(&hi.0);
    let mut v = [0u8; 16];
    for i in 0..16 {
        let j = idx.0[i];
        v[i] = if j & 0x80 != 0 { 0 } else { cat[(j & 0x1F) as usize] };
    }
    U8x16(v)
}

/// Compute the 64-bit "is not a continuation byte" mask for a 64-byte
/// block (Algorithm 3, line 8). Bit `i` is set iff `block[i]` is NOT a
/// UTF-8 continuation byte (i.e. it is ASCII or a leading byte).
///
/// A byte is a continuation byte iff its two most significant bits are
/// `10`, i.e. iff, read as a signed 8-bit integer, it is less than -64
/// (the paper phrases this as "all bytes less than -65 ... are
/// continuation bytes", comparing with <= -65 == < -64).
#[inline]
pub fn not_continuation_mask64(block: &[u8; 64]) -> u64 {
    let mut m = 0u64;
    for i in 0..64 {
        // continuation <=> (b & 0xC0) == 0x80
        let is_not_cont = (block[i] & 0xC0) != 0x80;
        m |= (is_not_cont as u64) << i;
    }
    m
}

/// Compute the 64-bit ASCII mask for a 64-byte block: bit `i` set iff
/// `block[i] < 0x80`.
#[inline]
pub fn ascii_mask64(block: &[u8; 64]) -> u64 {
    let mut m = 0u64;
    for i in 0..64 {
        m |= (((block[i] >> 7) ^ 1) as u64) << i;
    }
    m
}

/// True iff every byte of `block` is ASCII (fast path of Algorithm 3).
#[inline]
pub fn is_ascii_block(block: &[u8; 64]) -> bool {
    // OR-reduce then test the sign bit: one pass, autovectorizes.
    let mut acc = 0u8;
    for &b in block.iter() {
        acc |= b;
    }
    acc < 0x80
}

/// True iff every byte of the (arbitrary-length) slice is ASCII.
#[inline]
pub fn is_ascii(bytes: &[u8]) -> bool {
    let mut acc = 0u8;
    for &b in bytes {
        acc |= b;
    }
    acc < 0x80
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn not_continuation_mask_matches_definition() {
        let mut block = [0u8; 64];
        for i in 0..64 {
            block[i] = (i * 37 % 256) as u8;
        }
        let m = not_continuation_mask64(&block);
        for i in 0..64 {
            let expected = (block[i] & 0xC0) != 0x80;
            assert_eq!((m >> i) & 1 == 1, expected, "bit {i}");
        }
    }

    #[test]
    fn ascii_mask_matches_definition() {
        let mut block = [0u8; 64];
        for i in 0..64 {
            block[i] = (i * 41 % 256) as u8;
        }
        let m = ascii_mask64(&block);
        for i in 0..64 {
            assert_eq!((m >> i) & 1 == 1, block[i] < 0x80, "bit {i}");
        }
    }

    #[test]
    fn ascii_block_detection() {
        let block = [b'a'; 64];
        assert!(is_ascii_block(&block));
        let mut block2 = block;
        block2[63] = 0xC3;
        assert!(!is_ascii_block(&block2));
        assert!(is_ascii(b"hello world"));
        assert!(!is_ascii("héllo".as_bytes()));
        assert!(is_ascii(b""));
    }
}
