//! 8-lane 16-bit vector (the UTF-16 side of the transcoders).

use super::backend::SimdWords;
use super::U8x16;

/// An 8-lane vector of 16-bit code units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(transparent)]
pub struct U16x8(pub [u16; 8]);

impl U16x8 {
    /// The all-zero vector.
    pub const ZERO: U16x8 = U16x8([0; 8]);

    /// Load 8 little-endian 16-bit words from 16 bytes.
    #[inline]
    pub fn load_le_bytes(src: &[u8]) -> U16x8 {
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = u16::from_le_bytes([src[2 * i], src[2 * i + 1]]);
        }
        U16x8(v)
    }

    /// Load 8 words from a `&[u16]` slice (length >= 8).
    #[inline]
    pub fn load(src: &[u16]) -> U16x8 {
        let mut v = [0u16; 8];
        v.copy_from_slice(&src[..8]);
        U16x8(v)
    }

    /// Broadcast one word to all lanes.
    #[inline]
    pub fn splat(w: u16) -> U16x8 {
        U16x8([w; 8])
    }

    /// Store all lanes to the front of `dst` (`dst.len() >= 8`).
    #[inline]
    pub fn store(self, dst: &mut [u16]) {
        dst[..8].copy_from_slice(&self.0);
    }

    /// Reinterpret as 16 bytes (little-endian lane order).
    #[inline]
    pub fn to_bytes(self) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..8 {
            let [lo, hi] = self.0[i].to_le_bytes();
            v[2 * i] = lo;
            v[2 * i + 1] = hi;
        }
        U8x16(v)
    }

    /// Lane-wise bitwise AND.
    #[inline]
    pub fn and(self, rhs: U16x8) -> U16x8 {
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = self.0[i] & rhs.0[i];
        }
        U16x8(v)
    }

    /// Lane-wise bitwise OR.
    #[inline]
    pub fn or(self, rhs: U16x8) -> U16x8 {
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = self.0[i] | rhs.0[i];
        }
        U16x8(v)
    }

    /// Lane-wise logical shift right by a constant (`psrlw`).
    #[inline]
    pub fn shr<const N: u32>(self) -> U16x8 {
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = self.0[i] >> N;
        }
        U16x8(v)
    }

    /// Lane-wise shift left by a constant (`psllw`).
    #[inline]
    pub fn shl<const N: u32>(self) -> U16x8 {
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = self.0[i] << N;
        }
        U16x8(v)
    }

    /// Lane-wise unsigned less-than mask: `0xFFFF` where `self < rhs`.
    #[inline]
    pub fn lt_mask(self, rhs: U16x8) -> U16x8 {
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = if self.0[i] < rhs.0[i] { 0xFFFF } else { 0 };
        }
        U16x8(v)
    }

    /// 8-bit mask: bit `i` = MSB of lane `i` (the `packs`+`pmovmskb`
    /// idiom used to build the per-word bitsets of Algorithm 4). NEON
    /// has no `pmovmskb`; there the idiom is sign-shift, multiply by
    /// per-lane bit weights and a horizontal `addv` reduction.
    #[inline]
    pub fn movemask(self) -> u8 {
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; the loads read 8 words
        // (16 bytes) each from `self.0` and the constant weight table,
        // both `[u16; 8]`.
        unsafe {
            use core::arch::aarch64::*;
            const WEIGHTS: [u16; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
            let v = vld1q_u16(self.0.as_ptr());
            let bits = vshrq_n_u16(v, 15);
            let weighted = vmulq_u16(bits, vld1q_u16(WEIGHTS.as_ptr()));
            return vaddvq_u16(weighted) as u8;
        }
        #[allow(unreachable_code)]
        {
            let mut m = 0u8;
            for i in 0..8 {
                m |= ((self.0[i] >> 15) as u8) << i;
            }
            m
        }
    }

    /// OR-reduction of all lanes.
    #[inline]
    pub fn reduce_or(self) -> u16 {
        let mut acc = 0u16;
        for i in 0..8 {
            acc |= self.0[i];
        }
        acc
    }

    /// True iff any word is in the surrogate range `0xD800..=0xDFFF`.
    #[inline]
    pub fn has_surrogate(self) -> bool {
        let mut any = false;
        for i in 0..8 {
            any |= (self.0[i] & 0xF800) == 0xD800;
        }
        any
    }

    /// Lane-wise bitwise NOT.
    #[inline]
    pub fn not(self) -> U16x8 {
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = !self.0[i];
        }
        U16x8(v)
    }
}

impl SimdWords for U16x8 {
    const LANES: usize = 8;
    type Bytes = U8x16;

    #[inline]
    fn load(src: &[u16]) -> Self {
        U16x8::load(src)
    }
    #[inline]
    fn load_le_bytes(src: &[u8]) -> Self {
        U16x8::load_le_bytes(src)
    }
    #[inline]
    fn splat(w: u16) -> Self {
        U16x8::splat(w)
    }
    #[inline]
    fn store(self, dst: &mut [u16]) {
        U16x8::store(self, dst)
    }
    #[inline]
    fn to_bytes(self) -> U8x16 {
        U16x8::to_bytes(self)
    }
    #[inline]
    fn and(self, rhs: Self) -> Self {
        U16x8::and(self, rhs)
    }
    #[inline]
    fn or(self, rhs: Self) -> Self {
        U16x8::or(self, rhs)
    }
    #[inline]
    fn not(self) -> Self {
        U16x8::not(self)
    }
    #[inline]
    fn shr<const N: u32>(self) -> Self {
        U16x8::shr::<N>(self)
    }
    #[inline]
    fn shl<const N: u32>(self) -> Self {
        U16x8::shl::<N>(self)
    }
    #[inline]
    fn lt_mask(self, rhs: Self) -> Self {
        U16x8::lt_mask(self, rhs)
    }
    #[inline]
    fn movemask(self) -> u32 {
        U16x8::movemask(self) as u32
    }
    #[inline]
    fn reduce_or(self) -> u16 {
        U16x8::reduce_or(self)
    }
    #[inline]
    fn has_surrogate(self) -> bool {
        U16x8::has_surrogate(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn le_byte_roundtrip() {
        let bytes: Vec<u8> = (0..16).collect();
        let v = U16x8::load_le_bytes(&bytes);
        assert_eq!(v.0[0], 0x0100);
        assert_eq!(v.0[7], 0x0F0E);
        assert_eq!(v.to_bytes().0.to_vec(), bytes);
    }

    #[test]
    fn movemask_bits() {
        let v = U16x8([0x8000, 0, 0xFFFF, 0, 0, 0x8001, 0, 0]);
        assert_eq!(v.movemask(), (1 << 0) | (1 << 2) | (1 << 5));
    }

    #[test]
    fn surrogate_detection() {
        assert!(U16x8([0, 0, 0xD800, 0, 0, 0, 0, 0]).has_surrogate());
        assert!(U16x8([0xDFFF; 8]).has_surrogate());
        assert!(!U16x8([0xD7FF, 0xE000, 0x41, 0, 0, 0, 0, 0]).has_surrogate());
    }

    #[test]
    fn shifts() {
        let v = U16x8::splat(0x0F00);
        assert_eq!(v.shr::<4>(), U16x8::splat(0x00F0));
        assert_eq!(v.shl::<4>(), U16x8::splat(0xF000));
    }
}
