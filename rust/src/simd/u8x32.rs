//! 32-lane byte vector (the 256-bit side of the backend layer).

use super::backend::{kl_step_portable, SimdBytes};
use super::U8x16;

/// A 32-byte SIMD value with AVX2-equivalent semantics.
///
/// Loop-based operations autovectorize at `opt-level=3`; the operations
/// LLVM cannot synthesize from loops — `shuffle`/`lookup16` (`vpshufb`),
/// `prev` (`vperm2i128` + `vpalignr`), `movemask` (`vpmovmskb`) — carry
/// explicit `core::arch` implementations gated on
/// `target_feature = "avx2"`, with the portable loop as the fallback.
///
/// Note the `vpshufb` convention: at 32 lanes [`U8x32::shuffle`] and
/// [`U8x32::lookup16`] operate **per 16-byte half** (lane `i` selects
/// from its own half). Cross-half permutes go through
/// [`super::shuffle32`] explicitly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(transparent)]
pub struct U8x32(pub [u8; 32]);

impl U8x32 {
    /// The all-zero vector.
    pub const ZERO: U8x32 = U8x32([0; 32]);

    /// Load 32 bytes from the start of `src` (must have length >= 32).
    #[inline]
    pub fn load(src: &[u8]) -> U8x32 {
        let mut v = [0u8; 32];
        v.copy_from_slice(&src[..32]);
        U8x32(v)
    }

    /// Broadcast a single byte to all lanes.
    #[inline]
    pub fn splat(b: u8) -> U8x32 {
        U8x32([b; 32])
    }

    /// Store into the start of `dst` (must have length >= 32).
    #[inline]
    pub fn store(self, dst: &mut [u8]) {
        dst[..32].copy_from_slice(&self.0);
    }

    /// The two 16-byte halves, low half first.
    #[inline]
    pub fn to_halves(self) -> (U8x16, U8x16) {
        let mut lo = [0u8; 16];
        let mut hi = [0u8; 16];
        lo.copy_from_slice(&self.0[..16]);
        hi.copy_from_slice(&self.0[16..]);
        (U8x16(lo), U8x16(hi))
    }

    /// Lane-wise bitwise AND (`pand`).
    #[inline]
    pub fn and(self, rhs: U8x32) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..32 {
            v[i] = self.0[i] & rhs.0[i];
        }
        U8x32(v)
    }

    /// Lane-wise bitwise OR (`por`).
    #[inline]
    pub fn or(self, rhs: U8x32) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..32 {
            v[i] = self.0[i] | rhs.0[i];
        }
        U8x32(v)
    }

    /// Lane-wise bitwise XOR (`pxor`).
    #[inline]
    pub fn xor(self, rhs: U8x32) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..32 {
            v[i] = self.0[i] ^ rhs.0[i];
        }
        U8x32(v)
    }

    /// Lane-wise unsigned saturating subtraction (`vpsubusb`).
    #[inline]
    pub fn saturating_sub(self, rhs: U8x32) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..32 {
            v[i] = self.0[i].saturating_sub(rhs.0[i]);
        }
        U8x32(v)
    }

    /// Lane-wise logical shift right by a constant.
    #[inline]
    pub fn shr<const N: u32>(self) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..32 {
            v[i] = self.0[i] >> N;
        }
        U8x32(v)
    }

    /// `vpmovmskb`: bit `i` of the result is the MSB of lane `i`.
    #[inline]
    pub fn movemask(self) -> u32 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
        // SAFETY: avx2 is statically enabled by this cfg, so the
        // intrinsics are callable; the unaligned load reads exactly 32
        // bytes from `self.0`, a `[u8; 32]`.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm256_loadu_si256(self.0.as_ptr() as *const __m256i);
            return _mm256_movemask_epi8(a) as u32;
        }
        #[allow(unreachable_code)]
        {
            let mut m = 0u32;
            for i in 0..32 {
                m |= ((self.0[i] >> 7) as u32) << i;
            }
            m
        }
    }

    /// `vpshufb`: per 16-byte half, lane `i` is zero when
    /// `idx[i] & 0x80` is set, else the byte `idx[i] & 0x0F` of lane
    /// `i`'s own half.
    #[inline]
    pub fn shuffle(self, idx: U8x32) -> U8x32 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
        // SAFETY: avx2 is statically enabled by this cfg; the loads
        // read 32 bytes each from `self.0`/`idx.0` (`[u8; 32]`) and the
        // store writes 32 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm256_loadu_si256(self.0.as_ptr() as *const __m256i);
            let b = _mm256_loadu_si256(idx.0.as_ptr() as *const __m256i);
            let r = _mm256_shuffle_epi8(a, b);
            let mut out = [0u8; 32];
            _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, r);
            return U8x32(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 32];
            for i in 0..32 {
                let j = idx.0[i];
                v[i] = if j & 0x80 != 0 {
                    0
                } else {
                    self.0[(i & 0x10) | (j & 0x0F) as usize]
                };
            }
            U8x32(v)
        }
    }

    /// Nibble-table lookup: the 16-byte table broadcast to both halves,
    /// then `vpshufb`. Every lane of `self` must be in `[0, 16)`.
    #[inline]
    pub fn lookup16(self, table: &[u8; 16]) -> U8x32 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
        // SAFETY: avx2 (which implies sse2) is statically enabled by
        // this cfg; the loads read 16 bytes from `table` and 32 bytes
        // from `self.0`, and the store writes 32 bytes into the local
        // `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let t128 = _mm_loadu_si128(table.as_ptr() as *const __m128i);
            let t = _mm256_broadcastsi128_si256(t128);
            let i = _mm256_loadu_si256(self.0.as_ptr() as *const __m256i);
            let r = _mm256_shuffle_epi8(t, i);
            let mut out = [0u8; 32];
            _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, r);
            return U8x32(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 32];
            for i in 0..32 {
                v[i] = table[(self.0[i] & 0x0F) as usize];
            }
            U8x32(v)
        }
    }

    /// Cross-register lag: lane `i` is the byte `N` positions before
    /// lane `i` in the concatenated stream `prev_block ++ self`. Unlike
    /// [`U8x32::shuffle`], this *does* cross the 128-bit halves (the
    /// simdjson `vperm2i128` + `vpalignr` idiom).
    #[inline]
    pub fn prev<const N: usize>(self, prev_block: U8x32) -> U8x32 {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
        // SAFETY: avx2 is statically enabled by this cfg; the loads
        // read 32 bytes each from `self.0`/`prev_block.0` (`[u8; 32]`)
        // and the store writes 32 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let cur = _mm256_loadu_si256(self.0.as_ptr() as *const __m256i);
            let prv = _mm256_loadu_si256(prev_block.0.as_ptr() as *const __m256i);
            // [prev.high, cur.low]: the carry-in each 128-bit half needs.
            let shifted = _mm256_permute2x128_si256(prv, cur, 0x21);
            let r = match N {
                1 => _mm256_alignr_epi8(cur, shifted, 15),
                2 => _mm256_alignr_epi8(cur, shifted, 14),
                3 => _mm256_alignr_epi8(cur, shifted, 13),
                _ => unreachable!("prev<N> only used with N in 1..=3"),
            };
            let mut out = [0u8; 32];
            _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, r);
            return U8x32(out);
        }
        #[allow(unreachable_code)]
        {
            let mut cat = [0u8; 64];
            cat[..32].copy_from_slice(&prev_block.0);
            cat[32..].copy_from_slice(&self.0);
            let mut v = [0u8; 32];
            for i in 0..32 {
                v[i] = cat[32 + i - N];
            }
            U8x32(v)
        }
    }

    /// Byte interleave, low half, **sequential** across the register
    /// (the [`SimdBytes::interleave_lo`] convention): result lane `2i`
    /// is `self[i]`, lane `2i + 1` is `rhs[i]`, for `i < 16`. This is
    /// deliberately *not* `vpunpcklbw` (which interleaves per 128-bit
    /// half); the loop form is what the sequential semantics need, and
    /// LLVM synthesizes the shuffle from it.
    #[inline]
    pub fn interleave_lo(self, rhs: U8x32) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..16 {
            v[2 * i] = self.0[i];
            v[2 * i + 1] = rhs.0[i];
        }
        U8x32(v)
    }

    /// Byte interleave, high half (sequential — see
    /// [`U8x32::interleave_lo`]): result lane `2i` is `self[16 + i]`.
    #[inline]
    pub fn interleave_hi(self, rhs: U8x32) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..16 {
            v[2 * i] = self.0[16 + i];
            v[2 * i + 1] = rhs.0[16 + i];
        }
        U8x32(v)
    }

    /// True iff any lane is non-zero.
    #[inline]
    pub fn any(self) -> bool {
        let mut acc = 0u8;
        for i in 0..32 {
            acc |= self.0[i];
        }
        acc != 0
    }

    /// OR-reduction of all lanes.
    #[inline]
    pub fn reduce_or(self) -> u8 {
        let mut acc = 0u8;
        for i in 0..32 {
            acc |= self.0[i];
        }
        acc
    }

    /// True iff every lane is ASCII (MSB clear).
    #[inline]
    pub fn is_ascii(self) -> bool {
        self.reduce_or() < 0x80
    }
}

impl SimdBytes for U8x32 {
    const LANES: usize = 32;

    #[inline]
    fn zero() -> Self {
        U8x32::ZERO
    }
    #[inline]
    fn load(src: &[u8]) -> Self {
        U8x32::load(src)
    }
    #[inline]
    fn store(self, dst: &mut [u8]) {
        U8x32::store(self, dst)
    }
    #[inline]
    fn splat(b: u8) -> Self {
        U8x32::splat(b)
    }
    #[inline]
    fn from_fn(mut f: impl FnMut(usize) -> u8) -> Self {
        let mut v = [0u8; 32];
        for (i, lane) in v.iter_mut().enumerate() {
            *lane = f(i);
        }
        U8x32(v)
    }
    #[inline]
    fn and(self, rhs: Self) -> Self {
        U8x32::and(self, rhs)
    }
    #[inline]
    fn or(self, rhs: Self) -> Self {
        U8x32::or(self, rhs)
    }
    #[inline]
    fn xor(self, rhs: Self) -> Self {
        U8x32::xor(self, rhs)
    }
    #[inline]
    fn saturating_sub(self, rhs: Self) -> Self {
        U8x32::saturating_sub(self, rhs)
    }
    #[inline]
    fn shr<const N: u32>(self) -> Self {
        U8x32::shr::<N>(self)
    }
    #[inline]
    fn movemask(self) -> u64 {
        U8x32::movemask(self) as u64
    }
    #[inline]
    fn shuffle(self, idx: Self) -> Self {
        U8x32::shuffle(self, idx)
    }
    #[inline]
    fn lookup16(self, table: &[u8; 16]) -> Self {
        U8x32::lookup16(self, table)
    }
    #[inline]
    fn prev<const N: usize>(self, prev_block: Self) -> Self {
        U8x32::prev::<N>(self, prev_block)
    }
    #[inline]
    fn interleave_lo(self, rhs: Self) -> Self {
        U8x32::interleave_lo(self, rhs)
    }
    #[inline]
    fn interleave_hi(self, rhs: Self) -> Self {
        U8x32::interleave_hi(self, rhs)
    }
    #[inline]
    fn any(self) -> bool {
        U8x32::any(self)
    }
    #[inline]
    fn is_ascii(self) -> bool {
        U8x32::is_ascii(self)
    }

    #[inline]
    fn kl_step(
        self,
        prev_block: Self,
        prev_incomplete: Self,
        error_acc: Self,
        t1h: &[u8; 16],
        t1l: &[u8; 16],
        t2h: &[u8; 16],
    ) -> (Self, Self) {
        // The per-op AVX2 intrinsics (prev/lookup16) make the portable
        // formulation register-resident already; no fused path needed.
        kl_step_portable(self, prev_block, prev_incomplete, error_acc, t1h, t1l, t2h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_per_half_vpshufb() {
        let v = U8x32::from_fn(|i| 100 + i as u8);
        // Reverse within each half.
        let idx = U8x32::from_fn(|i| (15 - (i & 0x0F)) as u8);
        let out = v.shuffle(idx);
        for i in 0..16 {
            assert_eq!(out.0[i], 100 + (15 - i) as u8, "lo lane {i}");
            assert_eq!(out.0[16 + i], 100 + 16 + (15 - i) as u8, "hi lane {i}");
        }
        // High bit zeroes.
        let out2 = v.shuffle(U8x32::splat(0x80));
        assert_eq!(out2, U8x32::ZERO);
    }

    #[test]
    fn lookup16_broadcasts_the_table() {
        let table: [u8; 16] = core::array::from_fn(|i| (i * 3) as u8);
        let idx = U8x32::from_fn(|i| (i % 16) as u8);
        let out = idx.lookup16(&table);
        for i in 0..32 {
            assert_eq!(out.0[i], table[i % 16], "lane {i}");
        }
    }

    #[test]
    fn prev_crosses_the_half_boundary() {
        let prev = U8x32::from_fn(|i| i as u8);
        let cur = U8x32::from_fn(|i| 32 + i as u8);
        for (n, got) in
            [(1usize, cur.prev::<1>(prev)), (2, cur.prev::<2>(prev)), (3, cur.prev::<3>(prev))]
        {
            for i in 0..32 {
                let expected = (32 + i - n) as u8;
                assert_eq!(got.0[i], expected, "N={n} lane {i}");
            }
        }
    }

    #[test]
    fn movemask_matches_definition() {
        let v = U8x32::from_fn(|i| if i % 3 == 0 { 0x80 } else { 0x7F });
        let m = v.movemask();
        for i in 0..32 {
            assert_eq!((m >> i) & 1 == 1, i % 3 == 0, "bit {i}");
        }
    }

    #[test]
    fn interleave_is_sequential_not_per_half() {
        let a = U8x32::from_fn(|i| i as u8);
        let b = U8x32::from_fn(|i| 100 + i as u8);
        let lo = a.interleave_lo(b);
        let hi = a.interleave_hi(b);
        for i in 0..16 {
            assert_eq!(lo.0[2 * i], i as u8, "lo lane {i}");
            assert_eq!(lo.0[2 * i + 1], 100 + i as u8, "lo lane {i}");
            assert_eq!(hi.0[2 * i], 16 + i as u8, "hi lane {i}");
            assert_eq!(hi.0[2 * i + 1], 116 + i as u8, "hi lane {i}");
        }
    }

    #[test]
    fn halves_round_trip() {
        let v = U8x32::from_fn(|i| i as u8);
        let (lo, hi) = v.to_halves();
        assert_eq!(lo.0[0], 0);
        assert_eq!(lo.0[15], 15);
        assert_eq!(hi.0[0], 16);
        assert_eq!(hi.0[15], 31);
    }
}
