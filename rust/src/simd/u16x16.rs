//! 16-lane 16-bit vector (the 256-bit UTF-16 side).

use super::backend::SimdWords;
use super::U8x32;

/// A 16-lane vector of 16-bit code units. Loop-based; every operation
/// autovectorizes to AVX2 at `opt-level=3` when compiled for a CPU that
/// has it, and stays correct scalar code elsewhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(transparent)]
pub struct U16x16(pub [u16; 16]);

impl U16x16 {
    /// The all-zero vector.
    pub const ZERO: U16x16 = U16x16([0; 16]);

    /// Load 16 little-endian 16-bit words from 32 bytes.
    #[inline]
    pub fn load_le_bytes(src: &[u8]) -> U16x16 {
        let mut v = [0u16; 16];
        for i in 0..16 {
            v[i] = u16::from_le_bytes([src[2 * i], src[2 * i + 1]]);
        }
        U16x16(v)
    }

    /// Load 16 words from a `&[u16]` slice (length >= 16).
    #[inline]
    pub fn load(src: &[u16]) -> U16x16 {
        let mut v = [0u16; 16];
        v.copy_from_slice(&src[..16]);
        U16x16(v)
    }

    /// Broadcast one word to all lanes.
    #[inline]
    pub fn splat(w: u16) -> U16x16 {
        U16x16([w; 16])
    }

    /// Store all lanes to the front of `dst` (`dst.len() >= 16`).
    #[inline]
    pub fn store(self, dst: &mut [u16]) {
        dst[..16].copy_from_slice(&self.0);
    }

    /// Reinterpret as 32 bytes (little-endian lane order).
    #[inline]
    pub fn to_bytes(self) -> U8x32 {
        let mut v = [0u8; 32];
        for i in 0..16 {
            let [lo, hi] = self.0[i].to_le_bytes();
            v[2 * i] = lo;
            v[2 * i + 1] = hi;
        }
        U8x32(v)
    }

    /// Lane-wise bitwise AND.
    #[inline]
    pub fn and(self, rhs: U16x16) -> U16x16 {
        let mut v = [0u16; 16];
        for i in 0..16 {
            v[i] = self.0[i] & rhs.0[i];
        }
        U16x16(v)
    }

    /// Lane-wise bitwise OR.
    #[inline]
    pub fn or(self, rhs: U16x16) -> U16x16 {
        let mut v = [0u16; 16];
        for i in 0..16 {
            v[i] = self.0[i] | rhs.0[i];
        }
        U16x16(v)
    }

    /// Lane-wise bitwise NOT.
    #[inline]
    pub fn not(self) -> U16x16 {
        let mut v = [0u16; 16];
        for i in 0..16 {
            v[i] = !self.0[i];
        }
        U16x16(v)
    }

    /// Lane-wise logical shift right by a constant (`vpsrlw`).
    #[inline]
    pub fn shr<const N: u32>(self) -> U16x16 {
        let mut v = [0u16; 16];
        for i in 0..16 {
            v[i] = self.0[i] >> N;
        }
        U16x16(v)
    }

    /// Lane-wise shift left by a constant (`vpsllw`).
    #[inline]
    pub fn shl<const N: u32>(self) -> U16x16 {
        let mut v = [0u16; 16];
        for i in 0..16 {
            v[i] = self.0[i] << N;
        }
        U16x16(v)
    }

    /// Lane-wise unsigned less-than mask: `0xFFFF` where `self < rhs`.
    #[inline]
    pub fn lt_mask(self, rhs: U16x16) -> U16x16 {
        let mut v = [0u16; 16];
        for i in 0..16 {
            v[i] = if self.0[i] < rhs.0[i] { 0xFFFF } else { 0 };
        }
        U16x16(v)
    }

    /// 16-bit mask: bit `i` = MSB of lane `i`.
    #[inline]
    pub fn movemask(self) -> u16 {
        let mut m = 0u16;
        for i in 0..16 {
            m |= ((self.0[i] >> 15) as u16) << i;
        }
        m
    }

    /// OR-reduction of all lanes.
    #[inline]
    pub fn reduce_or(self) -> u16 {
        let mut acc = 0u16;
        for i in 0..16 {
            acc |= self.0[i];
        }
        acc
    }

    /// True iff any word is in the surrogate range `0xD800..=0xDFFF`.
    #[inline]
    pub fn has_surrogate(self) -> bool {
        let mut any = false;
        for i in 0..16 {
            any |= (self.0[i] & 0xF800) == 0xD800;
        }
        any
    }
}

impl SimdWords for U16x16 {
    const LANES: usize = 16;
    type Bytes = U8x32;

    #[inline]
    fn load(src: &[u16]) -> Self {
        U16x16::load(src)
    }
    #[inline]
    fn load_le_bytes(src: &[u8]) -> Self {
        U16x16::load_le_bytes(src)
    }
    #[inline]
    fn splat(w: u16) -> Self {
        U16x16::splat(w)
    }
    #[inline]
    fn store(self, dst: &mut [u16]) {
        U16x16::store(self, dst)
    }
    #[inline]
    fn to_bytes(self) -> U8x32 {
        U16x16::to_bytes(self)
    }
    #[inline]
    fn and(self, rhs: Self) -> Self {
        U16x16::and(self, rhs)
    }
    #[inline]
    fn or(self, rhs: Self) -> Self {
        U16x16::or(self, rhs)
    }
    #[inline]
    fn not(self) -> Self {
        U16x16::not(self)
    }
    #[inline]
    fn shr<const N: u32>(self) -> Self {
        U16x16::shr::<N>(self)
    }
    #[inline]
    fn shl<const N: u32>(self) -> Self {
        U16x16::shl::<N>(self)
    }
    #[inline]
    fn lt_mask(self, rhs: Self) -> Self {
        U16x16::lt_mask(self, rhs)
    }
    #[inline]
    fn movemask(self) -> u32 {
        U16x16::movemask(self) as u32
    }
    #[inline]
    fn reduce_or(self) -> u16 {
        U16x16::reduce_or(self)
    }
    #[inline]
    fn has_surrogate(self) -> bool {
        U16x16::has_surrogate(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn le_byte_roundtrip() {
        let bytes: Vec<u8> = (0..32).collect();
        let v = U16x16::load_le_bytes(&bytes);
        assert_eq!(v.0[0], 0x0100);
        assert_eq!(v.0[15], 0x1F1E);
        assert_eq!(v.to_bytes().0.to_vec(), bytes);
    }

    #[test]
    fn movemask_and_surrogates() {
        let mut w = [0u16; 16];
        w[1] = 0x8000;
        w[9] = 0xFFFF;
        assert_eq!(U16x16(w).movemask(), (1 << 1) | (1 << 9));
        w[9] = 0xD800;
        assert!(U16x16(w).has_surrogate());
        assert!(!U16x16([0xD7FF; 16]).has_surrogate());
    }
}
