//! 16-lane byte vector with x64/NEON-equivalent semantics.

use super::backend::{kl_step_portable, SimdBytes};

/// A 16-byte SIMD value. All operations are lane-wise unless noted.
///
/// The type is `repr(transparent)` over `[u8; 16]`. Arithmetic and
/// comparison loops autovectorize at `opt-level=3`; the operations LLVM
/// cannot synthesize from loops — `shuffle`/`lookup16` (`pshufb`),
/// `prev` (`palignr`), `movemask` (`pmovmskb`) — carry explicit
/// `core::arch` implementations gated on `target_feature = "ssse3"`
/// (enabled by the workspace's `target-cpu=native`) **and**, on
/// aarch64, NEON implementations (`vqtbl1q_u8` for the shuffles,
/// `ext` for `prev`, the weighted-bit `addv` reduction for
/// `movemask`, `zip1`/`zip2` for the interleaves — NEON is baseline on
/// aarch64, so no feature gate is needed), with the portable loop as
/// the fallback on other targets. This mirrors the paper's
/// multi-backend C++ (§6.1: "a high-level C++ approach which allows us
/// to easily support multiple processor instruction sets").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(transparent)]
pub struct U8x16(pub [u8; 16]);

impl U8x16 {
    /// The all-zero vector.
    pub const ZERO: U8x16 = U8x16([0; 16]);

    /// Load 16 bytes from the start of `src` (must have length >= 16).
    #[inline]
    pub fn load(src: &[u8]) -> U8x16 {
        let mut v = [0u8; 16];
        v.copy_from_slice(&src[..16]);
        U8x16(v)
    }

    /// Broadcast a single byte to all lanes.
    #[inline]
    pub fn splat(b: u8) -> U8x16 {
        U8x16([b; 16])
    }

    /// Store into the start of `dst` (must have length >= 16).
    #[inline]
    pub fn store(self, dst: &mut [u8]) {
        dst[..16].copy_from_slice(&self.0);
    }

    /// Lane-wise bitwise AND (`pand`).
    #[inline]
    pub fn and(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = self.0[i] & rhs.0[i];
        }
        U8x16(v)
    }

    /// Lane-wise bitwise OR (`por`).
    #[inline]
    pub fn or(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = self.0[i] | rhs.0[i];
        }
        U8x16(v)
    }

    /// Lane-wise bitwise XOR (`pxor`).
    #[inline]
    pub fn xor(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = self.0[i] ^ rhs.0[i];
        }
        U8x16(v)
    }

    /// Lane-wise unsigned saturating subtraction (`psubusb`).
    #[inline]
    pub fn saturating_sub(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = self.0[i].saturating_sub(rhs.0[i]);
        }
        U8x16(v)
    }

    /// Lane-wise wrapping addition (`paddb`).
    #[inline]
    pub fn wrapping_add(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = self.0[i].wrapping_add(rhs.0[i]);
        }
        U8x16(v)
    }

    /// Lane-wise logical shift right by a constant (`psrlw`+mask idiom).
    #[inline]
    pub fn shr<const N: u32>(self) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = self.0[i] >> N;
        }
        U8x16(v)
    }

    /// Lane-wise equality: `0xFF` where equal, `0x00` elsewhere (`pcmpeqb`).
    #[inline]
    pub fn eq_mask(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = if self.0[i] == rhs.0[i] { 0xFF } else { 0 };
        }
        U8x16(v)
    }

    /// Lane-wise unsigned less-than: `0xFF` where `self < rhs`.
    #[inline]
    pub fn lt_mask(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = if self.0[i] < rhs.0[i] { 0xFF } else { 0 };
        }
        U8x16(v)
    }

    /// Lane-wise signed greater-than (`pcmpgtb`): `0xFF` where
    /// `self as i8 > rhs as i8`.
    #[inline]
    pub fn gt_i8_mask(self, rhs: U8x16) -> U8x16 {
        let mut v = [0u8; 16];
        for i in 0..16 {
            v[i] = if (self.0[i] as i8) > (rhs.0[i] as i8) { 0xFF } else { 0 };
        }
        U8x16(v)
    }

    /// `pmovmskb`: bit `i` of the result is the most significant bit of
    /// lane `i` (lane 0 maps to the least significant bit).
    #[inline]
    pub fn movemask(self) -> u16 {
        #[cfg(all(target_arch = "x86_64", target_feature = "sse2"))]
        // SAFETY: sse2 is statically enabled by this cfg, so the
        // intrinsics are callable; the unaligned load reads exactly 16
        // bytes from `self.0`, a `[u8; 16]`.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm_loadu_si128(self.0.as_ptr() as *const __m128i);
            return _mm_movemask_epi8(a) as u16;
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; the loads read 16 bytes
        // from `self.0` and the constant weight table, both `[u8; 16]`.
        unsafe {
            use core::arch::aarch64::*;
            // NEON has no pmovmskb: isolate each MSB as a 0/1, weight
            // lane i of each half by 2^(i % 8), then one addv horizontal
            // sum per half builds the two mask bytes.
            const WEIGHTS: [u8; 16] = [1, 2, 4, 8, 16, 32, 64, 128, 1, 2, 4, 8, 16, 32, 64, 128];
            let v = vld1q_u8(self.0.as_ptr());
            let bits = vmulq_u8(vshrq_n_u8(v, 7), vld1q_u8(WEIGHTS.as_ptr()));
            let lo = vaddv_u8(vget_low_u8(bits)) as u16;
            let hi = vaddv_u8(vget_high_u8(bits)) as u16;
            return lo | (hi << 8);
        }
        #[allow(unreachable_code)]
        {
            let mut m = 0u16;
            for i in 0..16 {
                m |= ((self.0[i] >> 7) as u16) << i;
            }
            m
        }
    }

    /// `pshufb`: for each lane `i`, if `idx[i] & 0x80 != 0` the result
    /// lane is zero, otherwise it is `self[idx[i] & 0x0F]`.
    #[inline]
    pub fn shuffle(self, idx: U8x16) -> U8x16 {
        #[cfg(all(target_arch = "x86_64", target_feature = "ssse3"))]
        // SAFETY: ssse3 is statically enabled by this cfg; the loads
        // read 16 bytes each from `self.0`/`idx.0` (`[u8; 16]`) and the
        // store writes 16 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm_loadu_si128(self.0.as_ptr() as *const __m128i);
            let b = _mm_loadu_si128(idx.0.as_ptr() as *const __m128i);
            let r = _mm_shuffle_epi8(a, b);
            let mut out = [0u8; 16];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, r);
            return U8x16(out);
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; the loads read 16 bytes
        // each from `self.0`/`idx.0` (`[u8; 16]`) and the store writes
        // 16 bytes into the local `out` array.
        unsafe {
            use core::arch::aarch64::*;
            // tbl returns 0 for any index >= 16, so masking the index to
            // its low nibble plus the pshufb zero bit (0x8F) reproduces
            // pshufb exactly: a set high bit keeps the index >= 0x80,
            // well out of range.
            let v = vld1q_u8(self.0.as_ptr());
            let m = vandq_u8(vld1q_u8(idx.0.as_ptr()), vdupq_n_u8(0x8F));
            let r = vqtbl1q_u8(v, m);
            let mut out = [0u8; 16];
            vst1q_u8(out.as_mut_ptr(), r);
            return U8x16(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 16];
            for i in 0..16 {
                let j = idx.0[i];
                v[i] = if j & 0x80 != 0 { 0 } else { self.0[(j & 0x0F) as usize] };
            }
            U8x16(v)
        }
    }

    /// Nibble-table lookup: `table.shuffle(self)` where every lane of
    /// `self` must be in `[0, 16)`. This is how the Keiser–Lemire
    /// validator evaluates its three classification tables.
    #[inline]
    pub fn lookup16(self, table: &[u8; 16]) -> U8x16 {
        #[cfg(all(target_arch = "x86_64", target_feature = "ssse3"))]
        // SAFETY: ssse3 is statically enabled by this cfg; the loads
        // read 16 bytes each from `table` and `self.0` (`[u8; 16]`) and
        // the store writes 16 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let t = _mm_loadu_si128(table.as_ptr() as *const __m128i);
            // callers guarantee lanes < 16, so pshufb needs no masking
            let i = _mm_loadu_si128(self.0.as_ptr() as *const __m128i);
            let r = _mm_shuffle_epi8(t, i);
            let mut out = [0u8; 16];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, r);
            return U8x16(out);
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; the loads read 16 bytes
        // each from `table` and `self.0` (`[u8; 16]`) and the store
        // writes 16 bytes into the local `out` array.
        unsafe {
            use core::arch::aarch64::*;
            // Callers guarantee lanes < 16, so a bare tbl is the lookup.
            let t = vld1q_u8(table.as_ptr());
            let r = vqtbl1q_u8(t, vld1q_u8(self.0.as_ptr()));
            let mut out = [0u8; 16];
            vst1q_u8(out.as_mut_ptr(), r);
            return U8x16(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 16];
            for i in 0..16 {
                v[i] = table[(self.0[i] & 0x0F) as usize];
            }
            U8x16(v)
        }
    }

    /// `palignr`-style lag: returns a vector whose lane `i` is the byte
    /// that appeared `N` positions before lane `i` in the concatenated
    /// stream `prev ++ self` (used by the validator for `prev1/2/3`).
    #[inline]
    pub fn prev<const N: usize>(self, prev_block: U8x16) -> U8x16 {
        #[cfg(all(target_arch = "x86_64", target_feature = "ssse3"))]
        // SAFETY: ssse3 is statically enabled by this cfg; the loads
        // read 16 bytes each from `self.0`/`prev_block.0` (`[u8; 16]`)
        // and the store writes 16 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let cur = _mm_loadu_si128(self.0.as_ptr() as *const __m128i);
            let prv = _mm_loadu_si128(prev_block.0.as_ptr() as *const __m128i);
            // palignr concatenates prev:cur and shifts right by (16 - N)
            let r = match N {
                1 => _mm_alignr_epi8(cur, prv, 15),
                2 => _mm_alignr_epi8(cur, prv, 14),
                3 => _mm_alignr_epi8(cur, prv, 13),
                _ => unreachable!("prev<N> only used with N in 1..=3"),
            };
            let mut out = [0u8; 16];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, r);
            return U8x16(out);
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; the loads read 16 bytes
        // each from `prev_block.0`/`self.0` (`[u8; 16]`) and the store
        // writes 16 bytes into the local `out` array.
        unsafe {
            use core::arch::aarch64::*;
            // ext concatenates prev:cur and extracts 16 bytes starting
            // at lane 16 - N — the palignr idiom, one instruction.
            let prv = vld1q_u8(prev_block.0.as_ptr());
            let cur = vld1q_u8(self.0.as_ptr());
            let r = match N {
                1 => vextq_u8(prv, cur, 15),
                2 => vextq_u8(prv, cur, 14),
                3 => vextq_u8(prv, cur, 13),
                _ => unreachable!("prev<N> only used with N in 1..=3"),
            };
            let mut out = [0u8; 16];
            vst1q_u8(out.as_mut_ptr(), r);
            return U8x16(out);
        }
        #[allow(unreachable_code)]
        {
            let mut cat = [0u8; 32];
            cat[..16].copy_from_slice(&prev_block.0);
            cat[16..].copy_from_slice(&self.0);
            let mut v = [0u8; 16];
            for i in 0..16 {
                v[i] = cat[16 + i - N];
            }
            U8x16(v)
        }
    }

    /// Byte interleave, low half (`punpcklbw`): result lane `2i` is
    /// `self[i]`, lane `2i + 1` is `rhs[i]`, for `i < 8`.
    #[inline]
    pub fn interleave_lo(self, rhs: U8x16) -> U8x16 {
        #[cfg(all(target_arch = "x86_64", target_feature = "sse2"))]
        // SAFETY: sse2 is statically enabled by this cfg; the loads
        // read 16 bytes each from `self.0`/`rhs.0` (`[u8; 16]`) and the
        // store writes 16 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm_loadu_si128(self.0.as_ptr() as *const __m128i);
            let b = _mm_loadu_si128(rhs.0.as_ptr() as *const __m128i);
            let r = _mm_unpacklo_epi8(a, b);
            let mut out = [0u8; 16];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, r);
            return U8x16(out);
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; the loads read 16 bytes
        // each from `self.0`/`rhs.0` (`[u8; 16]`) and the store writes
        // 16 bytes into the local `out` array.
        unsafe {
            use core::arch::aarch64::*;
            let r = vzip1q_u8(vld1q_u8(self.0.as_ptr()), vld1q_u8(rhs.0.as_ptr()));
            let mut out = [0u8; 16];
            vst1q_u8(out.as_mut_ptr(), r);
            return U8x16(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 16];
            for i in 0..8 {
                v[2 * i] = self.0[i];
                v[2 * i + 1] = rhs.0[i];
            }
            U8x16(v)
        }
    }

    /// Byte interleave, high half (`punpckhbw`): result lane `2i` is
    /// `self[8 + i]`, lane `2i + 1` is `rhs[8 + i]`, for `i < 8`.
    #[inline]
    pub fn interleave_hi(self, rhs: U8x16) -> U8x16 {
        #[cfg(all(target_arch = "x86_64", target_feature = "sse2"))]
        // SAFETY: sse2 is statically enabled by this cfg; the loads
        // read 16 bytes each from `self.0`/`rhs.0` (`[u8; 16]`) and the
        // store writes 16 bytes into the local `out` array.
        unsafe {
            use core::arch::x86_64::*;
            let a = _mm_loadu_si128(self.0.as_ptr() as *const __m128i);
            let b = _mm_loadu_si128(rhs.0.as_ptr() as *const __m128i);
            let r = _mm_unpackhi_epi8(a, b);
            let mut out = [0u8; 16];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, r);
            return U8x16(out);
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; the loads read 16 bytes
        // each from `self.0`/`rhs.0` (`[u8; 16]`) and the store writes
        // 16 bytes into the local `out` array.
        unsafe {
            use core::arch::aarch64::*;
            let r = vzip2q_u8(vld1q_u8(self.0.as_ptr()), vld1q_u8(rhs.0.as_ptr()));
            let mut out = [0u8; 16];
            vst1q_u8(out.as_mut_ptr(), r);
            return U8x16(out);
        }
        #[allow(unreachable_code)]
        {
            let mut v = [0u8; 16];
            for i in 0..8 {
                v[2 * i] = self.0[8 + i];
                v[2 * i + 1] = rhs.0[8 + i];
            }
            U8x16(v)
        }
    }

    /// True iff any lane is non-zero.
    #[inline]
    pub fn any(self) -> bool {
        let mut acc = 0u8;
        for i in 0..16 {
            acc |= self.0[i];
        }
        acc != 0
    }

    /// OR-reduction of all lanes.
    #[inline]
    pub fn reduce_or(self) -> u8 {
        let mut acc = 0u8;
        for i in 0..16 {
            acc |= self.0[i];
        }
        acc
    }

    /// True iff every lane is ASCII (MSB clear).
    #[inline]
    pub fn is_ascii(self) -> bool {
        self.reduce_or() < 0x80
    }
}

impl SimdBytes for U8x16 {
    const LANES: usize = 16;

    #[inline]
    fn zero() -> Self {
        U8x16::ZERO
    }
    #[inline]
    fn load(src: &[u8]) -> Self {
        U8x16::load(src)
    }
    #[inline]
    fn store(self, dst: &mut [u8]) {
        U8x16::store(self, dst)
    }
    #[inline]
    fn splat(b: u8) -> Self {
        U8x16::splat(b)
    }
    #[inline]
    fn from_fn(mut f: impl FnMut(usize) -> u8) -> Self {
        let mut v = [0u8; 16];
        for (i, lane) in v.iter_mut().enumerate() {
            *lane = f(i);
        }
        U8x16(v)
    }
    #[inline]
    fn and(self, rhs: Self) -> Self {
        U8x16::and(self, rhs)
    }
    #[inline]
    fn or(self, rhs: Self) -> Self {
        U8x16::or(self, rhs)
    }
    #[inline]
    fn xor(self, rhs: Self) -> Self {
        U8x16::xor(self, rhs)
    }
    #[inline]
    fn saturating_sub(self, rhs: Self) -> Self {
        U8x16::saturating_sub(self, rhs)
    }
    #[inline]
    fn shr<const N: u32>(self) -> Self {
        U8x16::shr::<N>(self)
    }
    #[inline]
    fn movemask(self) -> u64 {
        U8x16::movemask(self) as u64
    }
    #[inline]
    fn shuffle(self, idx: Self) -> Self {
        U8x16::shuffle(self, idx)
    }
    #[inline]
    fn lookup16(self, table: &[u8; 16]) -> Self {
        U8x16::lookup16(self, table)
    }
    #[inline]
    fn prev<const N: usize>(self, prev_block: Self) -> Self {
        U8x16::prev::<N>(self, prev_block)
    }
    #[inline]
    fn interleave_lo(self, rhs: Self) -> Self {
        U8x16::interleave_lo(self, rhs)
    }
    #[inline]
    fn interleave_hi(self, rhs: Self) -> Self {
        U8x16::interleave_hi(self, rhs)
    }
    #[inline]
    fn any(self) -> bool {
        U8x16::any(self)
    }
    #[inline]
    fn is_ascii(self) -> bool {
        U8x16::is_ascii(self)
    }

    /// Fused SSSE3 Keiser–Lemire step: one load per state field, every
    /// intermediate stays in xmm registers. Semantically identical to
    /// the portable default (tested against it exhaustively).
    #[inline]
    fn kl_step(
        self,
        prev_block: Self,
        prev_incomplete: Self,
        error_acc: Self,
        t1h: &[u8; 16],
        t1l: &[u8; 16],
        t2h: &[u8; 16],
    ) -> (Self, Self) {
        #[cfg(all(target_arch = "x86_64", target_feature = "ssse3"))]
        // SAFETY: ssse3 is statically enabled by this cfg; every load
        // reads 16 bytes from a `[u8; 16]` (the four state vectors and
        // the three classification tables) and the two stores write 16
        // bytes each into the local `err_out`/`inc_out` arrays.
        unsafe {
            use core::arch::x86_64::*;
            let inp = _mm_loadu_si128(self.0.as_ptr() as *const __m128i);
            let low_nibble = _mm_set1_epi8(0x0F);
            let mut err = _mm_loadu_si128(error_acc.0.as_ptr() as *const __m128i);
            if _mm_movemask_epi8(inp) == 0 {
                // ASCII register.
                let inc = _mm_loadu_si128(prev_incomplete.0.as_ptr() as *const __m128i);
                err = _mm_or_si128(err, inc);
            } else {
                let prv = _mm_loadu_si128(prev_block.0.as_ptr() as *const __m128i);
                let prev1 = _mm_alignr_epi8(inp, prv, 15);
                // Three nibble classifications (pshufb table lookups).
                let t1h_v = _mm_loadu_si128(t1h.as_ptr() as *const __m128i);
                let t1l_v = _mm_loadu_si128(t1l.as_ptr() as *const __m128i);
                let t2h_v = _mm_loadu_si128(t2h.as_ptr() as *const __m128i);
                let hi1 = _mm_and_si128(_mm_srli_epi16(prev1, 4), low_nibble);
                let lo1 = _mm_and_si128(prev1, low_nibble);
                let hi2 = _mm_and_si128(_mm_srli_epi16(inp, 4), low_nibble);
                let sc = _mm_and_si128(
                    _mm_and_si128(_mm_shuffle_epi8(t1h_v, hi1), _mm_shuffle_epi8(t1l_v, lo1)),
                    _mm_shuffle_epi8(t2h_v, hi2),
                );
                // must-be-2/3-continuation check.
                let prev2 = _mm_alignr_epi8(inp, prv, 14);
                let prev3 = _mm_alignr_epi8(inp, prv, 13);
                let is_third = _mm_subs_epu8(prev2, _mm_set1_epi8((0xE0u8 - 0x80) as i8));
                let is_fourth = _mm_subs_epu8(prev3, _mm_set1_epi8((0xF0u8 - 0x80) as i8));
                let must32 = _mm_or_si128(is_third, is_fourth);
                let must32_80 = _mm_and_si128(must32, _mm_set1_epi8(0x80u8 as i8));
                err = _mm_or_si128(err, _mm_xor_si128(must32_80, sc));
            }
            // Incomplete-at-end mask.
            let max_value = <U8x16 as SimdBytes>::incomplete_max();
            let max_value = _mm_loadu_si128(max_value.0.as_ptr() as *const __m128i);
            let inc = _mm_subs_epu8(inp, max_value);
            let mut err_out = [0u8; 16];
            let mut inc_out = [0u8; 16];
            _mm_storeu_si128(err_out.as_mut_ptr() as *mut __m128i, err);
            _mm_storeu_si128(inc_out.as_mut_ptr() as *mut __m128i, inc);
            return (U8x16(err_out), U8x16(inc_out));
        }
        #[allow(unreachable_code)]
        {
            kl_step_portable(self, prev_block, prev_incomplete, error_acc, t1h, t1l, t2h)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_pshufb() {
        let v = U8x16([10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23, 24, 25]);
        // reverse
        let idx = U8x16([15, 14, 13, 12, 11, 10, 9, 8, 7, 6, 5, 4, 3, 2, 1, 0]);
        assert_eq!(
            v.shuffle(idx).0,
            [25, 24, 23, 22, 21, 20, 19, 18, 17, 16, 15, 14, 13, 12, 11, 10]
        );
        // high bit set -> zero
        let idx2 = U8x16([0x80, 0, 0xFF, 1, 0x80, 2, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
        let out = v.shuffle(idx2);
        assert_eq!(out.0[0], 0);
        assert_eq!(out.0[1], 10);
        assert_eq!(out.0[2], 0);
        assert_eq!(out.0[3], 11);
        // index wraps at 16 like pshufb (low 4 bits)
        let idx3 = U8x16([16 | 1; 16]); // 0x11 -> lane 1
        assert_eq!(v.shuffle(idx3).0, [11; 16]);
    }

    #[test]
    fn movemask_matches_sse() {
        let mut v = [0u8; 16];
        v[0] = 0x80;
        v[3] = 0xFF;
        v[15] = 0x90;
        assert_eq!(U8x16(v).movemask(), (1 << 0) | (1 << 3) | (1 << 15));
    }

    #[test]
    fn prev_lags_across_blocks() {
        let prev = U8x16([0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15]);
        let cur = U8x16([16, 17, 18, 19, 20, 21, 22, 23, 24, 25, 26, 27, 28, 29, 30, 31]);
        assert_eq!(cur.prev::<1>(prev).0[0], 15);
        assert_eq!(cur.prev::<1>(prev).0[1], 16);
        assert_eq!(cur.prev::<2>(prev).0[0], 14);
        assert_eq!(cur.prev::<3>(prev).0[0], 13);
        assert_eq!(cur.prev::<3>(prev).0[15], 28);
    }

    #[test]
    fn interleave_matches_punpck() {
        let a = U8x16([0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15]);
        let b =
            U8x16([100, 101, 102, 103, 104, 105, 106, 107, 108, 109, 110, 111, 112, 113, 114, 115]);
        assert_eq!(
            a.interleave_lo(b).0,
            [0, 100, 1, 101, 2, 102, 3, 103, 4, 104, 5, 105, 6, 106, 7, 107]
        );
        assert_eq!(
            a.interleave_hi(b).0,
            [8, 108, 9, 109, 10, 110, 11, 111, 12, 112, 13, 113, 14, 114, 15, 115]
        );
    }

    #[test]
    fn saturating_sub_saturates() {
        let a = U8x16::splat(0x10);
        let b = U8x16::splat(0x20);
        assert_eq!(a.saturating_sub(b), U8x16::ZERO);
        assert_eq!(b.saturating_sub(a), U8x16::splat(0x10));
    }

    #[test]
    fn comparison_masks() {
        let a = U8x16([0, 1, 0x7F, 0x80, 0xFF, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
        let b = U8x16::splat(0x80);
        let lt = a.lt_mask(b);
        assert_eq!(lt.0[0], 0xFF);
        assert_eq!(lt.0[2], 0xFF);
        assert_eq!(lt.0[3], 0);
        assert_eq!(lt.0[4], 0);
        // signed compare: 0xFF = -1 > -64(=0xC0)
        let gt = a.gt_i8_mask(U8x16::splat(0xC0));
        assert_eq!(gt.0[4], 0xFF); // -1 > -64
        assert_eq!(gt.0[3], 0); // -128 < -64
        assert_eq!(gt.0[0], 0xFF); // 0 > -64
    }
}
