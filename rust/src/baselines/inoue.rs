//! The Inoue et al. (2008) baseline: table-driven SIMD UTF-8 → UTF-16
//! transcoding, reimplemented from Algorithm 1 of the paper.
//!
//! Characteristics preserved from the original (§2):
//!
//! * no validation whatsoever;
//! * characters limited to 1–3 bytes (the Emoji dataset is
//!   "unsupported", exactly as Table 5 marks it);
//! * an eight-character main loop: a scalar pass over the eight lead
//!   bytes builds a base-3 index `g` (`g = 3g + (len-1)`), which selects
//!   two 16-byte permutation patterns from 3⁸ = 6561-entry tables
//!   (2 × 6561 × 16 B ≈ 205 KiB — the paper quotes "about 105 KiB" for
//!   the original's packed variant);
//! * a 32-byte load permuted into two registers — one holding each
//!   character's low bits, the other the remaining bits — then merged
//!   with shifts and masks;
//! * an ASCII fast path for eight-byte ASCII runs.

use crate::simd::{shuffle32, U8x16};
use crate::transcode::{TranscodeError, TranscodeResult, Utf8ToUtf16};
use std::sync::LazyLock;

/// Byte-length of a character from its lead byte, as Algorithm 1's
/// `[1,1,1,1,1,1,2,3]` table (indexed by `b >> 5`; no 4-byte support).
const LEN_FROM_HIGH3: [u8; 8] = [1, 1, 1, 1, 1, 1, 2, 3];

struct Patterns {
    /// For each `g`: 16-bit lanes `[second-to-last byte, third-to-last]`
    /// source indexes (0x80 where absent).
    pattern1: Vec<[u8; 16]>,
    /// For each `g`: 16-bit lanes `[last byte, —]` source indexes.
    pattern2: Vec<[u8; 16]>,
    /// Total bytes consumed by the eight characters (table metadata;
    /// the hot loop re-derives it during index construction).
    #[allow(dead_code)]
    consumed: Vec<u8>,
}

static PATTERNS: LazyLock<Patterns> = LazyLock::new(build_patterns);

fn build_patterns() -> Patterns {
    let n = 6561usize; // 3^8
    let mut pattern1 = vec![[0x80u8; 16]; n];
    let mut pattern2 = vec![[0x80u8; 16]; n];
    let mut consumed = vec![0u8; n];
    for g in 0..n {
        // g was built as g = 3*g + (len-1), so the FIRST character is the
        // most significant base-3 digit.
        let mut digits = [0u8; 8];
        let mut v = g;
        for k in (0..8).rev() {
            digits[k] = (v % 3) as u8;
            v /= 3;
        }
        let mut start = 0u8;
        for k in 0..8 {
            let len = digits[k] + 1;
            let last = start + len - 1;
            pattern2[g][2 * k] = last;
            if len >= 2 {
                pattern1[g][2 * k] = last - 1;
            }
            if len >= 3 {
                pattern1[g][2 * k + 1] = last - 2;
            }
            start += len;
        }
        consumed[g] = start;
    }
    Patterns { pattern1, pattern2, consumed }
}

/// The `Inoue et al.` engine of Table 5.
#[derive(Clone, Copy, Debug, Default)]
pub struct InoueTranscoder;

impl Utf8ToUtf16 for InoueTranscoder {
    fn name(&self) -> &'static str {
        "Inoue et al."
    }

    fn validating(&self) -> bool {
        false
    }

    fn supports_supplemental(&self) -> bool {
        false
    }

    fn convert(&self, src: &[u8], dst: &mut [u16]) -> TranscodeResult {
        let pats = &*PATTERNS;
        let mut p = 0usize;
        let mut q = 0usize;

        // Algorithm 1: while p + 32 < length(b)
        while p + 32 <= src.len() {
            if q + 8 > dst.len() {
                // Non-validating: output exhaustion is the only error.
                return Err(TranscodeError::output_buffer(p));
            }
            // ASCII fast path: next eight bytes.
            let mut acc = 0u8;
            for i in 0..8 {
                acc |= src[p + i];
            }
            if acc < 0x80 {
                for i in 0..8 {
                    dst[q + i] = src[p + i] as u16;
                }
                p += 8;
                q += 8;
                continue;
            }
            // Scalar pass over eight lead bytes building the base-3 index.
            let mut g = 0usize;
            let mut pp = p;
            for _ in 0..8 {
                let len = LEN_FROM_HIGH3[(src[pp] >> 5) as usize];
                g = 3 * g + (len - 1) as usize;
                pp += len as usize;
            }
            if pp > src.len() {
                break; // would read past the end; leave to the tail
            }
            let lo = U8x16::load(&src[p..]);
            let hi = U8x16::load(&src[p + 16..]);
            let v1 = shuffle32(lo, hi, U8x16(pats.pattern1[g]));
            let v2 = shuffle32(lo, hi, U8x16(pats.pattern2[g]));
            // Merge: low 6–7 bits from the last byte, middle 6 from the
            // second-to-last, top 4 from the third-to-last.
            for k in 0..8 {
                let w1 = u16::from_le_bytes([v1.0[2 * k], v1.0[2 * k + 1]]);
                let w2 = v2.0[2 * k] as u16;
                dst[q + k] =
                    (w2 & 0x7F) | ((w1 & 0x3F) << 6) | (((w1 >> 8) & 0x0F) << 12);
            }
            p = pp;
            q += 8;
        }

        // Conventional tail (non-validating, 1–3-byte only).
        while p < src.len() {
            if q >= dst.len() {
                return Err(TranscodeError::output_buffer(p));
            }
            let len = LEN_FROM_HIGH3[(src[p] >> 5) as usize] as usize;
            if p + len > src.len() {
                break;
            }
            dst[q] = match len {
                1 => src[p] as u16,
                2 => ((src[p] & 0x1F) as u16) << 6 | (src[p + 1] & 0x3F) as u16,
                _ => {
                    ((src[p] & 0x0F) as u16) << 12
                        | ((src[p + 1] & 0x3F) as u16) << 6
                        | (src[p + 2] & 0x3F) as u16
                }
            };
            p += len;
            q += 1;
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf8!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transcode::utf16_capacity_for;

    fn roundtrip_bmp(text: &str) {
        assert!(text.chars().all(|c| (c as u32) < 0x10000), "BMP-only baseline");
        let engine = InoueTranscoder;
        let mut dst = vec![0u16; utf16_capacity_for(text.len())];
        let n = engine.convert(text.as_bytes(), &mut dst).unwrap();
        assert_eq!(&dst[..n], &text.encode_utf16().collect::<Vec<_>>()[..], "{text}");
    }

    #[test]
    fn ascii_and_latin() {
        roundtrip_bmp(&"plain ascii ".repeat(20));
        roundtrip_bmp(&"déjà vu économie ".repeat(20));
    }

    #[test]
    fn two_and_three_byte_mixes() {
        roundtrip_bmp(&"русский текст ".repeat(20));
        roundtrip_bmp(&"漢字テスト ".repeat(20));
        roundtrip_bmp(&"mixed é漢 content ".repeat(20));
    }

    #[test]
    fn pattern_table_sizes() {
        let p = &*PATTERNS;
        assert_eq!(p.pattern1.len(), 6561);
        assert_eq!(p.pattern2.len(), 6561);
        // all-1-byte entry consumes 8 bytes, all-3-byte consumes 24
        assert_eq!(p.consumed[0], 8);
        assert_eq!(p.consumed[6560], 24);
    }

    #[test]
    fn short_inputs_via_tail() {
        roundtrip_bmp("é");
        roundtrip_bmp("漢");
        roundtrip_bmp("abc");
        roundtrip_bmp("");
    }
}
