//! The Steagall baseline (reference [18]): Hoehrmann's DFA augmented
//! with a SIMD ASCII fast path.
//!
//! Steagall's CppCon 2018 converter "relies primarily on a finite-state
//! machine with a fast SIMD-based ASCII path" (§6.1). We reproduce that
//! structure: whenever the next 16 bytes are all ASCII they are widened
//! wholesale; otherwise the DFA consumes bytes until it re-synchronizes
//! on a character boundary.

use crate::baselines::finite::{decode_step, ACCEPT, REJECT};
use crate::simd::U8x16;
use crate::transcode::{classify_utf8_error, TranscodeError, TranscodeResult, Utf8ToUtf16};

/// The `Steagall` engine of Tables 6 and 7.
#[derive(Clone, Copy, Debug, Default)]
pub struct SteagallTranscoder;

impl Utf8ToUtf16 for SteagallTranscoder {
    fn name(&self) -> &'static str {
        "Steagall"
    }

    fn validating(&self) -> bool {
        true
    }

    fn convert(&self, src: &[u8], dst: &mut [u16]) -> TranscodeResult {
        let mut p = 0usize;
        let mut q = 0usize;
        let mut state = ACCEPT;
        let mut codep = 0u32;
        // Start of the character the DFA is currently inside (for error
        // reporting; see the finite baseline).
        let mut char_start = 0usize;

        while p + 16 <= src.len() {
            if state == ACCEPT {
                let v = U8x16::load(&src[p..]);
                if v.is_ascii() {
                    if q + 16 > dst.len() {
                        return Err(TranscodeError::output_buffer(p));
                    }
                    for i in 0..16 {
                        dst[q + i] = v.0[i] as u16;
                    }
                    p += 16;
                    q += 16;
                    continue;
                }
            }
            // DFA over the next 16 bytes.
            let end = p + 16;
            while p < end {
                if state == ACCEPT {
                    char_start = p;
                }
                state = decode_step(state, &mut codep, src[p]);
                p += 1;
                if state == ACCEPT {
                    if q + 2 > dst.len() {
                        return Err(TranscodeError::output_buffer(char_start));
                    }
                    q += crate::scalar::encode_utf16_char(codep, &mut dst[q..]);
                } else if state == REJECT {
                    return Err(classify_utf8_error(src, char_start));
                }
            }
        }
        while p < src.len() {
            if state == ACCEPT {
                char_start = p;
            }
            state = decode_step(state, &mut codep, src[p]);
            p += 1;
            if state == ACCEPT {
                if q + 2 > dst.len() {
                    return Err(TranscodeError::output_buffer(char_start));
                }
                q += crate::scalar::encode_utf16_char(codep, &mut dst[q..]);
            } else if state == REJECT {
                return Err(classify_utf8_error(src, char_start));
            }
        }
        if state != ACCEPT {
            return Err(classify_utf8_error(src, char_start));
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf8!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transcode::utf16_capacity_for;

    #[test]
    fn matches_std_on_valid_text() {
        let engine = SteagallTranscoder;
        for text in [
            "pure ascii string that is long enough to hit the simd path repeatedly",
            "mixed é content 漢 with 🙂 interruptions between long ascii runs aaaaaaaa",
            "всё кириллицей без ascii вообще",
            "",
        ] {
            let mut dst = vec![0u16; utf16_capacity_for(text.len())];
            let n = engine.convert(text.as_bytes(), &mut dst).unwrap();
            assert_eq!(&dst[..n], &text.encode_utf16().collect::<Vec<_>>()[..], "{text}");
        }
    }

    #[test]
    fn rejects_invalid_at_any_alignment() {
        let engine = SteagallTranscoder;
        for pos in 0..48 {
            let mut buf = vec![b'a'; 64];
            buf[pos] = 0xC0;
            let mut dst = vec![0u16; utf16_capacity_for(buf.len())];
            let err = engine.convert(&buf, &mut dst).expect_err("invalid input");
            assert_eq!(err.position, pos, "pos {pos}");
        }
    }

    #[test]
    fn multibyte_straddling_chunk_boundary() {
        let engine = SteagallTranscoder;
        for pad in 10..20 {
            let text = format!("{}é{}", "a".repeat(pad), "b".repeat(20));
            let mut dst = vec![0u16; utf16_capacity_for(text.len())];
            let n = engine.convert(text.as_bytes(), &mut dst).unwrap();
            assert_eq!(&dst[..n], &text.encode_utf16().collect::<Vec<_>>()[..]);
        }
    }
}
