//! Every comparison system of the paper's evaluation (§6.1, Table 1),
//! reimplemented behind the same [`crate::transcode`] traits.
//!
//! | engine | paper row | kind |
//! |---|---|---|
//! | [`icu_like::IcuLikeTranscoder`] | ICU | careful scalar, both directions |
//! | [`llvm::LlvmTranscoder`] | LLVM | Unicode Consortium `ConvertUTF` port, both directions |
//! | [`finite::FiniteTranscoder`] | finite | Hoehrmann DFA, UTF-8 → UTF-16 |
//! | [`steagall::SteagallTranscoder`] | Steagall | DFA + SIMD ASCII path |
//! | [`inoue::InoueTranscoder`] | Inoue et al. | table-driven SIMD, 1–3-byte, non-validating |
//! | [`utf8lut::Utf8LutTranscoder`] | utf8lut | big-table SIMD, both directions |
//!
//! The paper's u8u16 (Cameron) bitstream transcoder is *not* rebuilt: it
//! is a patented design superseded by byte-stream approaches, and the
//! remaining set already spans the comparison space (scalar, DFA,
//! small-table SIMD, big-table SIMD). See DESIGN.md §Substitutions.

pub mod finite;
pub mod icu_like;
pub mod inoue;
pub mod llvm;
pub mod steagall;
pub mod utf8lut;
