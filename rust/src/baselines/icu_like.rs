//! The "ICU" baseline: a careful scalar transcoder in the style of
//! ICU's `U8_NEXT` / `U16_NEXT` macro loops with appendable sinks.
//!
//! The real International Components for Unicode is a much larger
//! library; what the paper benchmarks (`UnicodeString::fromUTF8`,
//! `UnicodeString::toUTF8String`) boils down to a guarded scalar decode
//! loop that (a) branches per character class, (b) re-checks capacity on
//! every append through a growable sink, and (c) funnels errors through
//! a sentinel value. We reproduce those three properties — they are what
//! give the ICU rows of Tables 5–10 their shape — without reimplementing
//! the rest of ICU.

use crate::transcode::{
    classify_utf16_error, classify_utf8_error, TranscodeError, TranscodeResult, Utf16ToUtf8,
    Utf8ToUtf16,
};

/// Sentinel produced by `u8_next` on malformed input (ICU uses a
/// negative `UChar32`).
const ERROR: i32 = -1;

/// ICU's `U8_NEXT`: decode one code point, returning the sentinel on
/// error. `i` advances past the consumed bytes (one byte on error).
#[inline]
fn u8_next(s: &[u8], i: &mut usize) -> i32 {
    let b0 = s[*i];
    *i += 1;
    if b0 < 0x80 {
        return b0 as i32;
    }
    // Lead-byte classification with ICU's U8_COUNT_TRAIL_BYTES-like
    // dispatch; trail bytes are validated with U8_IS_TRAIL plus the
    // per-lead second-byte ranges.
    let trail = |s: &[u8], i: &mut usize| -> Option<u8> {
        if *i >= s.len() {
            return None;
        }
        let b = s[*i];
        if b & 0xC0 != 0x80 {
            return None;
        }
        *i += 1;
        Some(b & 0x3F)
    };
    match b0 {
        0xC2..=0xDF => {
            let Some(t1) = trail(s, i) else { return ERROR };
            ((b0 as i32 & 0x1F) << 6) | t1 as i32
        }
        0xE0..=0xEF => {
            // second-byte range depends on the lead (E0/ED specials)
            if *i >= s.len() {
                return ERROR;
            }
            let b1 = s[*i];
            let ok = match b0 {
                0xE0 => (0xA0..=0xBF).contains(&b1),
                0xED => (0x80..=0x9F).contains(&b1),
                _ => (0x80..=0xBF).contains(&b1),
            };
            if !ok {
                return ERROR;
            }
            *i += 1;
            let Some(t2) = trail(s, i) else { return ERROR };
            ((b0 as i32 & 0x0F) << 12) | ((b1 as i32 & 0x3F) << 6) | t2 as i32
        }
        0xF0..=0xF4 => {
            if *i >= s.len() {
                return ERROR;
            }
            let b1 = s[*i];
            let ok = match b0 {
                0xF0 => (0x90..=0xBF).contains(&b1),
                0xF4 => (0x80..=0x8F).contains(&b1),
                _ => (0x80..=0xBF).contains(&b1),
            };
            if !ok {
                return ERROR;
            }
            *i += 1;
            let Some(t2) = trail(s, i) else { return ERROR };
            let Some(t3) = trail(s, i) else { return ERROR };
            ((b0 as i32 & 0x07) << 18) | ((b1 as i32 & 0x3F) << 12) | ((t2 as i32) << 6) | t3 as i32
        }
        _ => ERROR, // stray continuation, C0/C1, F5..FF
    }
}

/// The `ICU` engine of Tables 5–10.
#[derive(Clone, Copy, Debug, Default)]
pub struct IcuLikeTranscoder;

impl Utf8ToUtf16 for IcuLikeTranscoder {
    fn name(&self) -> &'static str {
        "ICU"
    }

    fn validating(&self) -> bool {
        true
    }

    fn convert(&self, src: &[u8], dst: &mut [u16]) -> TranscodeResult {
        let mut i = 0usize;
        let mut q = 0usize;
        while i < src.len() {
            // ICU funnels errors through a sentinel with no location;
            // the canonical kind/position come from the reference scan
            // at the character start.
            let start = i;
            let c = u8_next(src, &mut i);
            if c < 0 {
                return Err(classify_utf8_error(src, start));
            }
            // ICU's doAppend: capacity check on every code point.
            let c = c as u32;
            if c < 0x10000 {
                if q >= dst.len() {
                    return Err(TranscodeError::output_buffer(start));
                }
                dst[q] = c as u16;
                q += 1;
            } else {
                if q + 2 > dst.len() {
                    return Err(TranscodeError::output_buffer(start));
                }
                dst[q] = 0xD7C0u16.wrapping_add((c >> 10) as u16); // U16_LEAD
                dst[q + 1] = 0xDC00 | (c & 0x3FF) as u16; // U16_TRAIL
                q += 2;
            }
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf8!();
}

impl Utf16ToUtf8 for IcuLikeTranscoder {
    fn name(&self) -> &'static str {
        "ICU"
    }

    fn validating(&self) -> bool {
        true
    }

    fn convert(&self, src: &[u16], dst: &mut [u8]) -> TranscodeResult {
        let mut i = 0usize;
        let mut q = 0usize;
        while i < src.len() {
            // U16_NEXT
            let start = i;
            let w = src[i];
            i += 1;
            let c: u32 = if (0xD800..0xDC00).contains(&w) {
                if i >= src.len() || !(0xDC00..0xE000).contains(&src[i]) {
                    return Err(classify_utf16_error(src, start));
                }
                let lo = src[i];
                i += 1;
                0x10000 + (((w as u32 - 0xD800) << 10) | (lo as u32 - 0xDC00))
            } else if (0xDC00..0xE000).contains(&w) {
                return Err(classify_utf16_error(src, start));
            } else {
                w as u32
            };
            // U8_APPEND with capacity checks per byte group.
            let len = if c < 0x80 {
                1
            } else if c < 0x800 {
                2
            } else if c < 0x10000 {
                3
            } else {
                4
            };
            if q + len > dst.len() {
                return Err(TranscodeError::output_buffer(start));
            }
            q += crate::scalar::encode_utf8_char(c, &mut dst[q..]);
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf16!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transcode::{utf16_capacity_for, utf8_capacity_for};

    #[test]
    fn utf8_to_utf16_matches_std() {
        let engine = IcuLikeTranscoder;
        for text in ["hello", "héllo", "漢字テスト", "🙂🚀", "mix é漢🙂 end", ""] {
            let mut dst = vec![0u16; utf16_capacity_for(text.len())];
            let n = Utf8ToUtf16::convert(&engine, text.as_bytes(), &mut dst).unwrap();
            assert_eq!(&dst[..n], &text.encode_utf16().collect::<Vec<_>>()[..], "{text}");
        }
    }

    #[test]
    fn utf16_to_utf8_matches_std() {
        let engine = IcuLikeTranscoder;
        for text in ["hello", "héllo", "漢字テスト", "🙂🚀", "mix é漢🙂 end", ""] {
            let units: Vec<u16> = text.encode_utf16().collect();
            let mut dst = vec![0u8; utf8_capacity_for(units.len())];
            let n = Utf16ToUtf8::convert(&engine, &units, &mut dst).unwrap();
            assert_eq!(&dst[..n], text.as_bytes(), "{text}");
        }
    }

    #[test]
    fn validity_agrees_with_std_exhaustive_2byte() {
        let engine = IcuLikeTranscoder;
        let mut dst = vec![0u16; 32];
        for hi in 0..=255u8 {
            for lo in 0..=255u8 {
                let buf = [b'a', hi, lo, b'b'];
                let accepted = Utf8ToUtf16::convert(&engine, &buf, &mut dst).is_ok();
                assert_eq!(accepted, std::str::from_utf8(&buf).is_ok(), "{hi:02x}{lo:02x}");
            }
        }
    }

    #[test]
    fn exhaustive_3byte_lead_second_byte_space() {
        // For every 3-byte lead and every second byte, agree with std.
        let engine = IcuLikeTranscoder;
        let mut dst = vec![0u16; 32];
        for lead in 0xE0..=0xEFu8 {
            for b1 in 0..=255u8 {
                let buf = [lead, b1, 0x80];
                let accepted = Utf8ToUtf16::convert(&engine, &buf, &mut dst).is_ok();
                assert_eq!(accepted, std::str::from_utf8(&buf).is_ok(), "{lead:02x}{b1:02x}");
            }
        }
    }
}
