//! The utf8lut baseline (Gatilov 2019, reference [17]): big-table
//! vectorized transcoding, both directions.
//!
//! Characteristics preserved (§2, §6.7):
//!
//! * **UTF-8 → UTF-16**: one huge lookup table — here 2¹⁶ entries keyed
//!   by the 16-bit end-of-character bitset of a 16-byte window, each
//!   entry holding two expansion shuffle masks, a consumed count and a
//!   character count (≈ 2.4 MiB, the same scale as utf8lut's 2 MiB).
//!   Fewer instructions per byte than our approach, but poor cache
//!   behavior (Table 8: lowest instructions/byte, lowest IPC) and **no
//!   ASCII fast path** (§6.4 notes its absence).
//! * acceleration limited to the basic multilingual plane: windows
//!   containing 4-byte characters fall back to a scalar path (the paper
//!   observes utf8lut's "relatively low performance" on Emoji).
//! * two modes mirroring the upstream template parameters:
//!   `cmValidate` (full validation) and `cmFull` (convert any valid
//!   input, no validation).
//! * **UTF-16 → UTF-8**: a flat table-compress routine with no
//!   content-class specialization — which is why its Table 9/10 rows sit
//!   at a constant ~2.5 Gc/s regardless of language.

use crate::simd::{U16x8, U8x16};
use crate::transcode::{
    classify_utf8_error, TranscodeError, TranscodeResult, Utf16ToUtf8, Utf8ToUtf16,
};
use crate::validate::Utf8Validator;
use std::sync::LazyLock;

/// One big-table entry: expansion masks for characters 0–3 and 4–7 into
/// 32-bit lanes (last byte first, as in `tables::utf8_to_utf16`), bytes
/// consumed, characters produced, and whether a slow path is required.
#[derive(Clone, Copy)]
struct BigEntry {
    mask_a: [u8; 16],
    mask_b: [u8; 16],
    consumed: u8,
    chars: u8,
    slow: bool,
}

static BIG_TABLE: LazyLock<Vec<BigEntry>> = LazyLock::new(build_big_table);

fn build_big_table() -> Vec<BigEntry> {
    let mut table = Vec::with_capacity(1 << 16);
    for key in 0..(1u32 << 16) {
        let (lens, n, valid) = crate::tables::char_lens_from_mask(key, 16);
        // BMP only: a 4-byte char (or structural invalidity) forces the
        // slow path, as does an empty window.
        let usable = lens[..n].iter().take_while(|&&l| l <= 3).count();
        if usable == 0 || (!valid && usable < 8) {
            table.push(BigEntry {
                mask_a: [0x80; 16],
                mask_b: [0x80; 16],
                consumed: 0,
                chars: 0,
                slow: true,
            });
            continue;
        }
        let nchars = usable.min(8);
        let mut mask_a = [0x80u8; 16];
        let mut mask_b = [0x80u8; 16];
        let mut start = 0u8;
        for k in 0..nchars {
            let len = lens[k];
            let last = start + len - 1;
            let mask = if k < 4 { &mut mask_a } else { &mut mask_b };
            let base = (k % 4) * 4;
            for j in 0..len {
                mask[base + j as usize] = last - j;
            }
            start += len;
        }
        table.push(BigEntry { mask_a, mask_b, consumed: start, chars: nchars as u8, slow: false });
    }
    table
}

/// Compose four 1–3-byte characters from expanded 32-bit lanes
/// (identical bit math to our case 2 / Fig. 3).
#[inline]
fn compose4(perm: U8x16, dst: &mut [u16]) {
    for k in 0..4 {
        let lane = u32::from_le_bytes([
            perm.0[4 * k],
            perm.0[4 * k + 1],
            perm.0[4 * k + 2],
            perm.0[4 * k + 3],
        ]);
        let composed = (lane & 0x7F) | ((lane & 0x3F00) >> 2) | ((lane & 0x0F_0000) >> 4);
        dst[k] = composed as u16;
    }
}

/// Operating mode, mirroring utf8lut's `cmValidate` / `cmFull`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LutMode {
    /// Validate the input fully while converting.
    Validate,
    /// Convert any valid input without validation (garbage in → garbage
    /// out, memory-safe).
    Full,
}

/// The `utf8lut` engine of Tables 5–10.
#[derive(Clone, Copy, Debug)]
pub struct Utf8LutTranscoder {
    mode: LutMode,
}

impl Utf8LutTranscoder {
    /// The validating configuration (the paper's Table 6 column).
    pub const fn validating() -> Self {
        Utf8LutTranscoder { mode: LutMode::Validate }
    }

    /// The non-validating "full" configuration (Table 5).
    pub const fn full() -> Self {
        Utf8LutTranscoder { mode: LutMode::Full }
    }

    /// Approximate resident table size in bytes (for the §6.7 memory
    /// comparison).
    pub fn table_bytes() -> usize {
        BIG_TABLE.len() * std::mem::size_of::<BigEntry>()
    }
}

impl Utf8ToUtf16 for Utf8LutTranscoder {
    fn name(&self) -> &'static str {
        "utf8lut"
    }

    fn validating(&self) -> bool {
        self.mode == LutMode::Validate
    }

    fn convert(&self, src: &[u8], dst: &mut [u16]) -> TranscodeResult {
        let table = &*BIG_TABLE;
        let mut p = 0usize;
        let mut q = 0usize;
        let mut validator = Utf8Validator::<crate::simd::V128>::new();
        let mut v_pos = 0usize;

        // Need 17 readable bytes for the end-mask (the last end bit
        // depends on byte 16) plus the 16-byte window load.
        while p + 17 <= src.len() {
            if self.mode == LutMode::Validate {
                while v_pos + 16 <= src.len() && v_pos < p + 17 {
                    validator.push_vec(U8x16::load(&src[v_pos..]));
                    v_pos += 16;
                }
                if validator.has_error() {
                    // Validation runs ahead of conversion, so `p` is a
                    // character boundary with a valid prefix: the scalar
                    // re-scan pinpoints the error (see transcode::error).
                    return Err(classify_utf8_error(src, p));
                }
            }
            if q + 8 > dst.len() {
                return Err(TranscodeError::output_buffer(p));
            }
            // 16-bit end-of-character mask: byte i ends a char iff byte
            // i+1 is not a continuation.
            let mut key = 0u32;
            for i in 0..16 {
                let not_cont = (src[p + i + 1] & 0xC0) != 0x80;
                key |= (not_cont as u32) << i;
            }
            let entry = &table[key as usize];
            if entry.slow {
                // 4-byte character or degenerate window: scalar fallback
                // for one character.
                match crate::scalar::decode_utf8_char(&src[p..]) {
                    Ok((cp, len)) => {
                        q += crate::scalar::encode_utf16_char(cp, &mut dst[q..]);
                        p += len;
                    }
                    Err(e) => {
                        if self.mode == LutMode::Validate {
                            return Err(TranscodeError::new(e.kind, p));
                        }
                        p += 1; // skip garbage byte
                    }
                }
                continue;
            }
            let input = U8x16::load(&src[p..]);
            let perm_a = input.shuffle(U8x16(entry.mask_a));
            compose4(perm_a, &mut dst[q..]);
            if entry.chars > 4 {
                let perm_b = input.shuffle(U8x16(entry.mask_b));
                compose4(perm_b, &mut dst[q + 4..]);
            }
            q += entry.chars as usize;
            p += entry.consumed as usize;
        }

        // Tail.
        if self.mode == LutMode::Validate {
            validator.push_tail(&src[v_pos..]);
            if !validator.finish() {
                // As in our SIMD engine: if the validation frontier
                // stalled behind conversion near end-of-input, the
                // re-scan must start from 0 to stay exact.
                let from = if v_pos >= p { p } else { 0 };
                return Err(classify_utf8_error(src, from));
            }
        }
        // Scalar predictor: the tail is shorter than one window stride.
        if q + crate::count::utf16_len_from_utf8_scalar(&src[p..]) > dst.len() {
            return Err(TranscodeError::output_buffer(p));
        }
        q += crate::scalar::utf8_to_utf16_unchecked(&src[p..], &mut dst[q..]);
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf8!();
}

impl Utf16ToUtf8 for Utf8LutTranscoder {
    fn name(&self) -> &'static str {
        "utf8lut"
    }

    fn validating(&self) -> bool {
        true // surrogate handling always checks, as in Algorithm 4 case 4
    }

    fn convert(&self, src: &[u16], dst: &mut [u8]) -> TranscodeResult {
        // Flat routine: every register takes the general 1–3-byte
        // table-compress path (no ASCII / 2-byte specialization), with a
        // scalar fallback for surrogates. This reproduces utf8lut's flat
        // ~2.5 Gc/s row in Tables 9/10.
        let mut p = 0usize;
        let mut q = 0usize;
        while p + 8 <= src.len() {
            if q + 32 > dst.len() {
                return Err(TranscodeError::output_buffer(p));
            }
            let v = U16x8::load(&src[p..]);
            if !v.has_surrogate() {
                q += crate::transcode::utf16_to_utf8::one_two_three_half_pub(
                    &src[p..p + 4],
                    &mut dst[q..],
                );
                q += crate::transcode::utf16_to_utf8::one_two_three_half_pub(
                    &src[p + 4..p + 8],
                    &mut dst[q..],
                );
                p += 8;
                continue;
            }
            let limit = p + 8;
            while p < limit.min(src.len()) {
                match crate::scalar::decode_utf16_char(&src[p..]) {
                    Ok((cp, n)) => {
                        p += n;
                        q += crate::scalar::encode_utf8_char(cp, &mut dst[q..]);
                    }
                    Err(e) => return Err(TranscodeError::new(e.kind, p)),
                }
            }
        }
        while p < src.len() {
            if q + 4 > dst.len() {
                return Err(TranscodeError::output_buffer(p));
            }
            match crate::scalar::decode_utf16_char(&src[p..]) {
                Ok((cp, n)) => {
                    p += n;
                    q += crate::scalar::encode_utf8_char(cp, &mut dst[q..]);
                }
                Err(e) => return Err(TranscodeError::new(e.kind, p)),
            }
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf16!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transcode::{utf16_capacity_for, utf8_capacity_for};

    fn roundtrip(text: &str) {
        for engine in [Utf8LutTranscoder::validating(), Utf8LutTranscoder::full()] {
            let mut dst = vec![0u16; utf16_capacity_for(text.len())];
            let n = Utf8ToUtf16::convert(&engine, text.as_bytes(), &mut dst).unwrap();
            assert_eq!(
                &dst[..n],
                &text.encode_utf16().collect::<Vec<_>>()[..],
                "{text} mode {:?}",
                engine.mode
            );
        }
    }

    #[test]
    fn bmp_content() {
        roundtrip(&"ascii only text here ".repeat(10));
        roundtrip(&"déjà vu économie ".repeat(10));
        roundtrip(&"русский текст пример ".repeat(10));
        roundtrip(&"漢字テスト文字列 ".repeat(10));
        roundtrip("");
        roundtrip("é");
    }

    #[test]
    fn supplemental_via_slow_path() {
        roundtrip(&"a🙂b🚀c".repeat(10));
        roundtrip(&"🙂🚀🌍💡".repeat(10));
    }

    #[test]
    fn validate_mode_rejects_invalid() {
        let engine = Utf8LutTranscoder::validating();
        let mut bad = "é".repeat(30).into_bytes();
        bad[17] = 0xFF;
        let mut dst = vec![0u16; utf16_capacity_for(bad.len())];
        let err = Utf8ToUtf16::convert(&engine, &bad, &mut dst).expect_err("invalid");
        let expected = std::str::from_utf8(&bad).unwrap_err().valid_up_to();
        assert_eq!(err.position, expected);
    }

    #[test]
    fn full_mode_survives_garbage() {
        let engine = Utf8LutTranscoder::full();
        let mut state = 99u64;
        for len in [0usize, 20, 64, 257] {
            let mut soup = vec![0u8; len];
            for b in soup.iter_mut() {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                *b = (state >> 33) as u8;
            }
            let mut dst = vec![0u16; utf16_capacity_for(len)];
            let _ = Utf8ToUtf16::convert(&engine, &soup, &mut dst);
        }
    }

    #[test]
    fn utf16_to_utf8_roundtrip() {
        let engine = Utf8LutTranscoder::validating();
        for text in ["hello", "éé漢漢", "🙂🚀", "mix é漢🙂 with ascii tail", ""] {
            let units: Vec<u16> = text.encode_utf16().collect();
            let mut dst = vec![0u8; utf8_capacity_for(units.len())];
            let n = Utf16ToUtf8::convert(&engine, &units, &mut dst).unwrap();
            assert_eq!(&dst[..n], text.as_bytes(), "{text}");
        }
    }

    #[test]
    fn table_is_big() {
        // The point of this baseline: a table in the megabytes.
        assert!(Utf8LutTranscoder::table_bytes() > 2_000_000);
    }
}
