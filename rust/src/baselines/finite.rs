//! The "finite" baseline: Hoehrmann's pure finite-state UTF-8 → UTF-16
//! transcoder (reference [19] of the paper; last modified 2010).
//!
//! The decoder is a DFA over byte classes: every byte maps to one of 12
//! character classes, and a 9-state transition table (states stored
//! premultiplied by 12) advances one byte at a time while accumulating
//! the code point. State 0 accepts, state 12 rejects. This is the exact
//! table from the original publication.

use crate::transcode::{classify_utf8_error, TranscodeError, TranscodeResult, Utf8ToUtf16};

/// Byte → character-class table (first half of Hoehrmann's `utf8d`).
pub const CLASS: [u8; 256] = build_class_table();

const fn build_class_table() -> [u8; 256] {
    let mut t = [0u8; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = match b {
            0x00..=0x7F => 0,
            0x80..=0x8F => 1,
            0x90..=0x9F => 9,
            0xA0..=0xBF => 7,
            0xC0..=0xC1 => 8,
            0xC2..=0xDF => 2,
            0xE0 => 10,
            0xE1..=0xEC => 3,
            0xED => 4,
            0xEE..=0xEF => 3,
            0xF0 => 11,
            0xF1..=0xF3 => 6,
            0xF4 => 5,
            _ => 8, // 0xF5..=0xFF
        };
        b += 1;
    }
    t
}

/// Accepting state.
pub const ACCEPT: u8 = 0;
/// Rejecting state.
pub const REJECT: u8 = 12;

/// State-transition table (second half of Hoehrmann's `utf8d`):
/// `TRANS[state + class]`, states premultiplied by 12.
#[rustfmt::skip]
pub const TRANS: [u8; 108] = [
    // s0 (accept)
     0, 12, 24, 36, 60, 96, 84, 12, 12, 12, 48, 72,
    // s1 (reject)
    12, 12, 12, 12, 12, 12, 12, 12, 12, 12, 12, 12,
    // s2: expect one continuation
    12,  0, 12, 12, 12, 12, 12,  0, 12,  0, 12, 12,
    // s3: expect two continuations
    12, 24, 12, 12, 12, 12, 12, 24, 12, 24, 12, 12,
    // s4: after E0 (continuation restricted to A0..BF)
    12, 12, 12, 12, 12, 12, 12, 24, 12, 12, 12, 12,
    // s5: after ED (continuation restricted to 80..9F)
    12, 24, 12, 12, 12, 12, 12, 12, 12, 24, 12, 12,
    // s6: after F0 (continuation restricted to 90..BF)
    12, 12, 12, 12, 12, 12, 12, 36, 12, 36, 12, 12,
    // s7: after F1..F3 (any continuation, two more follow)
    12, 36, 12, 12, 12, 12, 12, 36, 12, 36, 12, 12,
    // s8: after F4 (continuation restricted to 80..8F)
    12, 36, 12, 12, 12, 12, 12, 12, 12, 12, 12, 12,
];

/// One DFA step. Returns the new state; `codep` accumulates data bits.
#[inline]
pub fn decode_step(state: u8, codep: &mut u32, byte: u8) -> u8 {
    let class = CLASS[byte as usize];
    *codep = if state != ACCEPT {
        ((byte & 0x3F) as u32) | (*codep << 6)
    } else {
        ((0xFFu32 >> class) & byte as u32) as u32
    };
    TRANS[(state + class) as usize]
}

/// The `finite` engine of Tables 6 and 7.
#[derive(Clone, Copy, Debug, Default)]
pub struct FiniteTranscoder;

impl Utf8ToUtf16 for FiniteTranscoder {
    fn name(&self) -> &'static str {
        "finite"
    }

    fn validating(&self) -> bool {
        true // the DFA rejects malformed input by construction
    }

    fn convert(&self, src: &[u8], dst: &mut [u16]) -> TranscodeResult {
        let mut state = ACCEPT;
        let mut codep = 0u32;
        let mut q = 0usize;
        // The DFA rejects mid-character; `char_start` remembers where the
        // offending character began so the reference scan can report the
        // canonical kind/position.
        let mut char_start = 0usize;
        for (p, &b) in src.iter().enumerate() {
            if state == ACCEPT {
                char_start = p;
            }
            state = decode_step(state, &mut codep, b);
            if state == ACCEPT {
                if q + 2 > dst.len() {
                    return Err(TranscodeError::output_buffer(char_start));
                }
                q += crate::scalar::encode_utf16_char(codep, &mut dst[q..]);
            } else if state == REJECT {
                return Err(classify_utf8_error(src, char_start));
            }
        }
        if state != ACCEPT {
            // Truncated sequence at end of input.
            return Err(classify_utf8_error(src, char_start));
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf8!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transcode::utf16_capacity_for;

    #[test]
    fn matches_std_on_valid_text() {
        let engine = FiniteTranscoder;
        for text in [
            "hello",
            "héllo wörld",
            "漢字テスト",
            "🙂🚀🌍",
            "mixed ascii é漢🙂 text with all classes",
            "",
        ] {
            let mut dst = vec![0u16; utf16_capacity_for(text.len())];
            let n = engine.convert(text.as_bytes(), &mut dst).unwrap();
            assert_eq!(&dst[..n], &text.encode_utf16().collect::<Vec<_>>()[..], "{text}");
        }
    }

    #[test]
    fn agrees_with_std_validity_exhaustive_2byte() {
        let engine = FiniteTranscoder;
        let mut dst = vec![0u16; 32];
        for hi in 0..=255u8 {
            for lo in 0..=255u8 {
                let buf = [b'a', hi, lo, b'b'];
                let accepted = engine.convert(&buf, &mut dst).is_ok();
                assert_eq!(accepted, std::str::from_utf8(&buf).is_ok(), "{hi:02x}{lo:02x}");
            }
        }
    }

    #[test]
    fn rejects_truncation_and_surrogates() {
        let engine = FiniteTranscoder;
        let mut dst = vec![0u16; 32];
        assert!(engine.convert(&[0xE4], &mut dst).is_err());
        assert!(engine.convert(&[0xED, 0xA0, 0x80], &mut dst).is_err());
        assert!(engine.convert(&[0xF4, 0x90, 0x80, 0x80], &mut dst).is_err());
        assert!(engine.convert(&[0xF4, 0x8F, 0xBF, 0xBF], &mut dst).is_ok());
    }
}
