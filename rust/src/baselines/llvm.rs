//! The "LLVM" baseline: a faithful port of the Unicode Consortium
//! `ConvertUTF.c` routines that the LLVM project ships (last revised
//! September 2001 — §6.1). Both directions, with validation.
//!
//! The port preserves the original structure — the `trailingBytesForUTF8`
//! table, the magic `offsetsFromUTF8` subtraction, the fall-through
//! accumulation switch and the `isLegalUTF8` range checks — because the
//! paper benchmarks precisely that code shape (one branchy pass,
//! character at a time, no SIMD).

use crate::transcode::{
    classify_utf16_error, classify_utf8_error, TranscodeError, TranscodeResult, Utf16ToUtf8,
    Utf8ToUtf16,
};

/// `trailingBytesForUTF8`: extra bytes following each lead byte.
const TRAILING_BYTES: [u8; 256] = build_trailing();

const fn build_trailing() -> [u8; 256] {
    let mut t = [0u8; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = match b {
            0x00..=0xBF => 0,
            0xC0..=0xDF => 1,
            0xE0..=0xEF => 2,
            0xF0..=0xF7 => 3,
            0xF8..=0xFB => 4,
            _ => 5,
        };
        b += 1;
    }
    t
}

/// `offsetsFromUTF8`: the magic values subtracted after accumulation.
const OFFSETS: [u32; 6] =
    [0x0000_0000, 0x0000_3080, 0x000E_2080, 0x03C8_2080, 0xFA08_2080, 0x8208_2080];

/// `firstByteMark`: OR-mask for the leading byte when encoding.
const FIRST_BYTE_MARK: [u8; 7] = [0x00, 0x00, 0xC0, 0xE0, 0xF0, 0xF8, 0xFC];

const UNI_SUR_HIGH_START: u32 = 0xD800;
const UNI_SUR_LOW_START: u32 = 0xDC00;
const UNI_SUR_LOW_END: u32 = 0xDFFF;
const UNI_MAX_LEGAL_UTF32: u32 = 0x0010_FFFF;
const HALF_BASE: u32 = 0x0001_0000;

/// `isLegalUTF8`: validate `length` bytes starting at `src[0]`.
fn is_legal_utf8(src: &[u8], length: usize) -> bool {
    // Walk backwards, as the original does.
    let a = |i: usize| src[i];
    match length {
        4 => {
            if !(0x80..=0xBF).contains(&a(3)) {
                return false;
            }
            if !(0x80..=0xBF).contains(&a(2)) {
                return false;
            }
            if !legal_second_byte(a(0), a(1)) {
                return false;
            }
            src[0] <= 0xF4
        }
        3 => {
            if !(0x80..=0xBF).contains(&a(2)) {
                return false;
            }
            if !legal_second_byte(a(0), a(1)) {
                return false;
            }
            src[0] <= 0xF4
        }
        2 => {
            if !legal_second_byte(a(0), a(1)) {
                return false;
            }
            src[0] <= 0xF4
        }
        1 => src[0] < 0x80,
        _ => false,
    }
}

#[inline]
fn legal_second_byte(b0: u8, b1: u8) -> bool {
    if b1 > 0xBF {
        return false;
    }
    match b0 {
        0xE0 => b1 >= 0xA0,
        0xED => b1 <= 0x9F,
        0xF0 => b1 >= 0x90,
        0xF4 => b1 <= 0x8F,
        _ => {
            // For the default case the original also rejects lead bytes
            // in 0x80..0xC1 via `case 1`-style checks: a two-byte lead
            // must be >= 0xC2.
            b1 >= 0x80 && b0 >= 0xC2
        }
    }
}

/// The `LLVM` engine of Tables 6, 7, 9 and 10.
#[derive(Clone, Copy, Debug, Default)]
pub struct LlvmTranscoder;

impl Utf8ToUtf16 for LlvmTranscoder {
    fn name(&self) -> &'static str {
        "LLVM"
    }

    fn validating(&self) -> bool {
        true
    }

    fn convert(&self, src: &[u8], dst: &mut [u16]) -> TranscodeResult {
        let mut p = 0usize;
        let mut q = 0usize;
        while p < src.len() {
            // `p` is the start of the current character: every failure
            // below reports the canonical error found by the reference
            // scan from here (the prefix is already converted, so valid).
            let extra = TRAILING_BYTES[src[p] as usize] as usize;
            if p + extra >= src.len() {
                return Err(classify_utf8_error(src, p)); // sourceExhausted
            }
            if !is_legal_utf8(&src[p..], extra + 1) {
                return Err(classify_utf8_error(src, p)); // sourceIllegal
            }
            // Fall-through accumulation, as in the original switch.
            let mut ch: u32 = 0;
            for i in 0..=extra {
                ch = (ch << 6).wrapping_add(src[p + i] as u32);
            }
            ch = ch.wrapping_sub(OFFSETS[extra]);

            if ch <= 0xFFFF {
                if (UNI_SUR_HIGH_START..=UNI_SUR_LOW_END).contains(&ch) {
                    return Err(classify_utf8_error(src, p));
                }
                if q >= dst.len() {
                    return Err(TranscodeError::output_buffer(p)); // targetExhausted
                }
                dst[q] = ch as u16;
                q += 1;
            } else if ch > UNI_MAX_LEGAL_UTF32 {
                return Err(classify_utf8_error(src, p));
            } else {
                if q + 2 > dst.len() {
                    return Err(TranscodeError::output_buffer(p));
                }
                let ch = ch - HALF_BASE;
                dst[q] = ((ch >> 10) + UNI_SUR_HIGH_START) as u16;
                dst[q + 1] = ((ch & 0x3FF) + UNI_SUR_LOW_START) as u16;
                q += 2;
            }
            p += extra + 1;
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf8!();
}

impl Utf16ToUtf8 for LlvmTranscoder {
    fn name(&self) -> &'static str {
        "LLVM"
    }

    fn validating(&self) -> bool {
        true
    }

    fn convert(&self, src: &[u16], dst: &mut [u8]) -> TranscodeResult {
        let mut p = 0usize;
        let mut q = 0usize;
        while p < src.len() {
            let start = p;
            let mut ch = src[p] as u32;
            p += 1;
            if (UNI_SUR_HIGH_START..UNI_SUR_LOW_START).contains(&ch) {
                // High surrogate: must be followed by a low surrogate.
                if p >= src.len() {
                    return Err(classify_utf16_error(src, start));
                }
                let ch2 = src[p] as u32;
                if !(UNI_SUR_LOW_START..=UNI_SUR_LOW_END).contains(&ch2) {
                    return Err(classify_utf16_error(src, start));
                }
                ch = ((ch - UNI_SUR_HIGH_START) << 10) + (ch2 - UNI_SUR_LOW_START) + HALF_BASE;
                p += 1;
            } else if (UNI_SUR_LOW_START..=UNI_SUR_LOW_END).contains(&ch) {
                return Err(classify_utf16_error(src, start)); // unpaired low
            }

            let bytes_to_write = if ch < 0x80 {
                1
            } else if ch < 0x800 {
                2
            } else if ch < 0x10000 {
                3
            } else {
                4
            };
            if q + bytes_to_write > dst.len() {
                return Err(TranscodeError::output_buffer(start));
            }
            // Fall-through write, back to front, as in the original.
            const BYTE_MASK: u32 = 0xBF;
            const BYTE_MARK: u32 = 0x80;
            let mut tmp = ch;
            for i in (1..bytes_to_write).rev() {
                dst[q + i] = ((tmp | BYTE_MARK) & BYTE_MASK) as u8;
                tmp >>= 6;
            }
            dst[q] = (tmp | FIRST_BYTE_MARK[bytes_to_write] as u32) as u8;
            q += bytes_to_write;
        }
        Ok(q)
    }

    // `convert` is write-only over `dst` (audited): eligible for the
    // uninitialized-buffer `*_to_vec` fast paths.
    crate::transcode::uninit_to_vec_utf16!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transcode::{utf16_capacity_for, utf8_capacity_for};

    #[test]
    fn utf8_to_utf16_matches_std() {
        let engine = LlvmTranscoder;
        for text in ["hello", "héllo", "漢字", "🙂🚀", "mix é漢🙂 end", ""] {
            let mut dst = vec![0u16; utf16_capacity_for(text.len())];
            let n = Utf8ToUtf16::convert(&engine, text.as_bytes(), &mut dst).unwrap();
            assert_eq!(&dst[..n], &text.encode_utf16().collect::<Vec<_>>()[..], "{text}");
        }
    }

    #[test]
    fn utf16_to_utf8_matches_std() {
        let engine = LlvmTranscoder;
        for text in ["hello", "héllo", "漢字", "🙂🚀", "mix é漢🙂 end", ""] {
            let units: Vec<u16> = text.encode_utf16().collect();
            let mut dst = vec![0u8; utf8_capacity_for(units.len())];
            let n = Utf16ToUtf8::convert(&engine, &units, &mut dst).unwrap();
            assert_eq!(&dst[..n], text.as_bytes(), "{text}");
        }
    }

    #[test]
    fn validity_agrees_with_std_exhaustive_2byte() {
        let engine = LlvmTranscoder;
        let mut dst = vec![0u16; 32];
        for hi in 0..=255u8 {
            for lo in 0..=255u8 {
                let buf = [b'a', hi, lo, b'b'];
                let accepted = Utf8ToUtf16::convert(&engine, &buf, &mut dst).is_ok();
                assert_eq!(accepted, std::str::from_utf8(&buf).is_ok(), "{hi:02x}{lo:02x}");
            }
        }
    }

    #[test]
    fn rejects_unpaired_surrogates() {
        let engine = LlvmTranscoder;
        let mut dst = vec![0u8; 64];
        assert!(Utf16ToUtf8::convert(&engine, &[0xD800], &mut dst).is_err());
        assert!(Utf16ToUtf8::convert(&engine, &[0xD800, 0x41], &mut dst).is_err());
        assert!(Utf16ToUtf8::convert(&engine, &[0xDC00], &mut dst).is_err());
    }
}
