"""Counting-kernel mirror: differential tests vs CPython.

The Rust `count` module sizes exact allocations with SIMD counting
kernels; `compile.kernels.validate` mirrors them as whole-array numpy
mask algebra. CPython is the oracle for valid input
(``len(b.decode())``, ``len(s.encode('utf-16-le')) // 2``,
``decode('utf-16-le', errors='replace')`` re-encoded for the
unpaired-surrogate convention); a scalar port of the Rust reference
covers arbitrary invalid input.

Standalone from test_kernel.py: needs neither `hypothesis` nor the jax
validation kernel.
"""

import random
import struct

from compile.kernels.validate import (
    count_utf16_code_points,
    count_utf8_code_points,
    utf16_len_from_utf8,
    utf8_len_from_utf16,
)

SAMPLES = [
    "",
    "a",
    "plain ascii, long enough to cross a sixty-four byte block boundary!!",
    "héllo wörld",
    "пример текста на русском языке",
    "漢字テスト、これは長めの文字列です。",
    "🙂🚀🌍💡🔥🎉",
    "mixed é漢🙂 text with a bit of everything: ascii, éé, 漢字, 🚀🚀 end",
]


def scalar_utf8_len_from_utf16(words):
    """Port of the Rust scalar reference (the seed predictor)."""
    n = 0
    i = 0
    while i < len(words):
        w = words[i]
        if w < 0x80:
            n += 1
        elif w < 0x800:
            n += 2
        elif 0xD800 <= w < 0xDC00:
            if i + 1 < len(words) and 0xDC00 <= words[i + 1] < 0xE000:
                i += 1
                n += 4
            else:
                n += 3
        else:
            n += 3
        i += 1
    return n


def test_utf8_counts_match_cpython_on_valid_text():
    for text in SAMPLES:
        for repeats in (1, 7):
            s = text * repeats
            b = s.encode("utf-8")
            assert utf16_len_from_utf8(b) == len(s.encode("utf-16-le")) // 2, s
            assert count_utf8_code_points(b) == len(b.decode()), s


def test_utf16_counts_match_cpython_on_valid_text():
    for text in SAMPLES:
        for repeats in (1, 7):
            s = text * repeats
            words = list(struct.unpack("<%dH" % (len(s.encode("utf-16-le")) // 2),
                                       s.encode("utf-16-le")))
            assert utf8_len_from_utf16(words) == len(s.encode("utf-8")), s
            assert count_utf16_code_points(words) == len(s), s


def test_utf8_counts_are_total_on_garbage():
    rng = random.Random(0xC0017)
    for _ in range(400):
        data = bytes(rng.randrange(256) for _ in range(rng.randrange(0, 300)))
        # Reference: the per-byte formula, byte at a time.
        words = sum(((b & 0xC0) != 0x80) + (b >= 0xF0) for b in data)
        cps = sum((b & 0xC0) != 0x80 for b in data)
        assert utf16_len_from_utf8(data) == words
        assert count_utf8_code_points(data) == cps


def test_utf16_len_matches_replace_oracle_on_unpaired_surrogates():
    # The 3-bytes-per-unpaired-surrogate convention is exactly the width
    # of U+FFFD, so CPython's errors='replace' decode re-encoded as
    # UTF-8 is an independent oracle for arbitrary word soup.
    alphabet = [0x41, 0x7F, 0x80, 0x7FF, 0x800, 0xD7FF, 0xD800, 0xDBFF,
                0xDC00, 0xDFFF, 0xE000, 0xFFFD, 0xFFFF]
    rng = random.Random(0x5EED)
    for _ in range(400):
        n = rng.randrange(0, 120)
        words = [rng.choice(alphabet) for _ in range(n)]
        raw = struct.pack("<%dH" % n, *words)
        oracle = len(raw.decode("utf-16-le", errors="replace").encode("utf-8"))
        assert utf8_len_from_utf16(words) == oracle, words
        assert utf8_len_from_utf16(words) == scalar_utf8_len_from_utf16(words), words


def test_pair_detection_edges():
    cases = [
        ([0xDC00], 3),
        ([0xD800], 3),
        ([0xD800, 0x41], 4),
        ([0xD83D, 0xDE42], 4),
        ([0xDC00, 0xD800], 6),
        ([0xD800, 0xD800, 0xDC00], 7),
        ([0xD800, 0xDC00, 0xDC00], 7),
    ]
    for words, expected in cases:
        assert utf8_len_from_utf16(words) == expected, words
    # code points: high surrogates merge into their pair, lows stand.
    assert count_utf16_code_points([0x41, 0xD83D, 0xDE42]) == 2
    assert count_utf16_code_points([0xD800, 0xD800]) == 0
    assert count_utf16_code_points([]) == 0
