"""Kernel vs reference-oracle correctness: the CORE L1 signal.

Every Pallas kernel (interpret mode) is compared against CPython's own
codecs via the ``ref`` oracles, on curated texts, adversarial byte
soups, and hypothesis-generated code-point sequences.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    utf16_to_utf8_blocks,
    utf8_to_utf16_blocks,
    validate_utf8_blocks,
)
from compile.kernels import ref
from compile.kernels.utf8_to_utf16 import BLOCK_ROWS

TEXTS = [
    "",
    "a",
    "hello world, plain ascii that spans multiple blocks " * 5,
    "héllo wörld, déjà vu, ça va " * 8,
    "русский текст пример " * 10,
    "漢字テスト文字列 " * 12,
    "한국어 텍스트 " * 10,
    "हिन्दी पाठ " * 10,
    "🙂🚀🌍💡" * 20,
    "mixed a é 漢 🙂 content with all four classes " * 6,
]


def run_utf8_to_utf16(data: bytes):
    blocks, lengths = ref.blocks_from_utf8(data)
    blocks, lengths = ref.pad_batch(blocks, lengths, BLOCK_ROWS)
    words, counts = utf8_to_utf16_blocks(blocks, lengths)
    return blocks, lengths, np.asarray(words), np.asarray(counts)


@pytest.mark.parametrize("text", TEXTS, ids=range(len(TEXTS)))
def test_utf8_to_utf16_matches_ref(text):
    data = text.encode("utf-8")
    blocks, lengths, words, counts = run_utf8_to_utf16(data)
    ref_words, ref_counts = ref.utf8_to_utf16_ref(blocks, lengths)
    np.testing.assert_array_equal(counts, ref_counts)
    np.testing.assert_array_equal(words, ref_words)


@pytest.mark.parametrize("text", TEXTS, ids=range(len(TEXTS)))
def test_reassembled_stream_matches_python(text):
    """End-to-end: concatenated per-block outputs == full-string UTF-16."""
    data = text.encode("utf-8")
    blocks, lengths, words, counts = run_utf8_to_utf16(data)
    stream = []
    for r in range(blocks.shape[0]):
        stream.extend(words[r, : counts[r]].tolist())
    expected = np.frombuffer(text.encode("utf-16-le"), dtype=np.uint16).tolist()
    assert stream == expected


@pytest.mark.parametrize("text", TEXTS, ids=range(len(TEXTS)))
def test_validate_accepts_valid(text):
    blocks, lengths = ref.blocks_from_utf8(text.encode("utf-8"))
    blocks, lengths = ref.pad_batch(blocks, lengths, BLOCK_ROWS)
    valid = np.asarray(validate_utf8_blocks(blocks, lengths))
    assert valid.all()


BAD_SEQUENCES = [
    b"\x80",  # stray continuation
    b"\xc0\x80",  # overlong 2-byte
    b"\xc1\xbf",
    b"\xc2",  # truncated
    b"\xe0\x80\x80",  # overlong 3-byte
    b"\xe0\x9f\xbf",
    b"\xed\xa0\x80",  # surrogate
    b"\xf0\x80\x80\x80",  # overlong 4-byte
    b"\xf4\x90\x80\x80",  # > U+10FFFF
    b"\xf5\x80\x80\x80",
    b"\xff",
    b"abc\x80def",
    b"\xc2a",  # lead + ascii
    b"\xe1\x80\xc0\x80",
]


@pytest.mark.parametrize("bad", BAD_SEQUENCES, ids=range(len(BAD_SEQUENCES)))
def test_validate_rejects_invalid(bad):
    # Embed at a few offsets inside otherwise-valid content.
    for prefix in [b"", b"xy", b"x" * 40]:
        data = prefix + bad
        blocks, lengths = ref.blocks_from_utf8(data)
        blocks, lengths = ref.pad_batch(blocks, lengths, BLOCK_ROWS)
        valid = np.asarray(validate_utf8_blocks(blocks, lengths))
        expected = ref.validate_utf8_ref(blocks, lengths)
        np.testing.assert_array_equal(valid, expected)
        assert not valid.all()


def test_validate_agrees_with_ref_on_byte_soup():
    rng = np.random.default_rng(42)
    for _ in range(24):
        n = int(rng.integers(0, 64))
        row = np.zeros((1, 64), dtype=np.int32)
        row[0, :n] = rng.integers(0, 256, size=n)
        lengths = np.array([n], dtype=np.int32)
        blocks, lens = ref.pad_batch(row, lengths, BLOCK_ROWS)
        valid = np.asarray(validate_utf8_blocks(blocks, lens))
        expected = ref.validate_utf8_ref(blocks, lens)
        np.testing.assert_array_equal(valid, expected, err_msg=str(row[0, :n]))


def run_utf16_to_utf8(units):
    blocks, lengths = ref.blocks_from_utf16(units)
    blocks, lengths = ref.pad_batch(blocks, lengths, BLOCK_ROWS)
    out, counts, valid = utf16_to_utf8_blocks(blocks, lengths)
    return blocks, lengths, np.asarray(out), np.asarray(counts), np.asarray(valid)


@pytest.mark.parametrize("text", TEXTS, ids=range(len(TEXTS)))
def test_utf16_to_utf8_matches_ref(text):
    units = np.frombuffer(text.encode("utf-16-le"), dtype=np.uint16).tolist()
    blocks, lengths, out, counts, valid = run_utf16_to_utf8(units)
    ref_out, ref_counts, ref_valid = ref.utf16_to_utf8_ref(blocks, lengths)
    np.testing.assert_array_equal(valid, ref_valid)
    np.testing.assert_array_equal(counts, ref_counts)
    np.testing.assert_array_equal(out, ref_out)


def test_utf16_lone_surrogates_flagged():
    for units in [[0xD800], [0xDC00], [0x41, 0xD800, 0x42], [0xDC00, 0xD800]]:
        blocks, lengths, out, counts, valid = run_utf16_to_utf8(units)
        ref_out, ref_counts, ref_valid = ref.utf16_to_utf8_ref(blocks, lengths)
        np.testing.assert_array_equal(valid, ref_valid)
        assert not valid[0]


# ---------- hypothesis sweeps ----------

scalar_values = st.integers(0, 0x10FFFF).filter(
    lambda c: not (0xD800 <= c <= 0xDFFF)
)


@settings(max_examples=40, deadline=None)
@given(st.lists(scalar_values, min_size=0, max_size=300))
def test_hypothesis_utf8_roundtrip(cps):
    text = "".join(chr(c) for c in cps)
    data = text.encode("utf-8")
    blocks, lengths, words, counts = run_utf8_to_utf16(data)
    ref_words, ref_counts = ref.utf8_to_utf16_ref(blocks, lengths)
    np.testing.assert_array_equal(counts, ref_counts)
    np.testing.assert_array_equal(words, ref_words)


@settings(max_examples=40, deadline=None)
@given(st.lists(scalar_values, min_size=0, max_size=300))
def test_hypothesis_utf16_roundtrip(cps):
    text = "".join(chr(c) for c in cps)
    units = np.frombuffer(text.encode("utf-16-le"), dtype=np.uint16).tolist()
    blocks, lengths, out, counts, valid = run_utf16_to_utf8(units)
    ref_out, ref_counts, ref_valid = ref.utf16_to_utf8_ref(blocks, lengths)
    np.testing.assert_array_equal(valid, ref_valid)
    np.testing.assert_array_equal(counts, ref_counts)
    np.testing.assert_array_equal(out, ref_out)


@settings(max_examples=30, deadline=None)
@given(st.binary(min_size=0, max_size=200))
def test_hypothesis_validator_agrees_with_python(data):
    blocks, lengths = ref.blocks_from_utf8(data)
    # blocks_from_utf8 trims to boundaries assuming valid-ish input; for
    # arbitrary soup force single-block rows instead.
    rows = []
    lens = []
    for i in range(0, max(len(data), 1), 64):
        chunk = data[i : i + 64]
        row = np.zeros(64, dtype=np.int32)
        row[: len(chunk)] = np.frombuffer(chunk, dtype=np.uint8)
        rows.append(row)
        lens.append(len(chunk))
    blocks = np.stack(rows) if rows else np.zeros((1, 64), np.int32)
    lengths = np.array(lens, dtype=np.int32)
    blocks, lengths = ref.pad_batch(blocks, lengths, BLOCK_ROWS)
    valid = np.asarray(validate_utf8_blocks(blocks, lengths))
    expected = ref.validate_utf8_ref(blocks, lengths)
    np.testing.assert_array_equal(valid, expected)
