"""Failure-record classification: the Rust `TranscodeError` mirror.

Standalone from test_kernel.py so it runs without `hypothesis`; only the
`error_records` test needs the (jax) validation kernel.
"""

import random

import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.validate import (
    ERROR_KINDS,
    REPLACEMENT,
    classify_utf8_error,
    error_records,
    transcode_lossy,
)
from compile.kernels.utf8_to_utf16 import BLOCK_ROWS

BAD_SEQUENCES = [
    b"\x80",
    b"\xc0\x80",
    b"\xc1\xbf",
    b"\xc2",
    b"\xe0\x80\x80",
    b"\xe0\x9f\xbf",
    b"\xed\xa0\x80",
    b"\xf0\x80\x80\x80",
    b"\xf4\x90\x80\x80",
    b"\xf5\x80\x80\x80",
    b"\xff",
    b"abc\x80def",
    b"\xc2a",
    b"\xe1\x80\xc0\x80",
]


@pytest.mark.parametrize("bad", BAD_SEQUENCES, ids=range(len(BAD_SEQUENCES)))
def test_classifier_position_matches_cpython(bad):
    """The mirrored classifier reports CPython's UnicodeDecodeError.start."""
    for prefix in [b"", b"xy", "héllo ".encode("utf-8")]:
        data = prefix + bad
        rec = classify_utf8_error(data)
        try:
            data.decode("utf-8")
        except UnicodeDecodeError as e:
            assert rec is not None, data
            assert rec["position"] == e.start, data
            assert rec["kind"] in ERROR_KINDS, rec
        else:
            assert rec is None, data


def test_classifier_kinds_match_rust_convention():
    cases = {
        b"\xff": "header_bits",
        b"\x80": "too_long",
        b"\xc2": "too_short",
        b"\xc0\x80": "overlong",
        b"\xe0\x9f\xbf": "overlong",
        b"\xed\xa0\x80": "surrogate",
        b"\xf4\x90\x80\x80": "too_large",
        b"\xf5\x80\x80\x80": "too_large",
    }
    for data, kind in cases.items():
        assert classify_utf8_error(data)["kind"] == kind, data


def test_classifier_accepts_valid_text():
    for text in ["", "ascii", "héllo wörld", "漢字テスト", "🙂🚀"]:
        assert classify_utf8_error(text.encode("utf-8")) is None, text


def _cpython_lossy_utf16(data: bytes):
    """Oracle: CPython's WHATWG replacement decode, as UTF-16 units."""
    s = data.decode("utf-8", errors="replace")
    out = []
    for ch in s:
        cp = ord(ch)
        if cp < 0x10000:
            out.append(cp)
        else:
            v = cp - 0x10000
            out.extend([0xD800 + (v >> 10), 0xDC00 + (v & 0x3FF)])
    return out


@pytest.mark.parametrize("bad", BAD_SEQUENCES, ids=range(len(BAD_SEQUENCES)))
def test_transcode_lossy_matches_cpython_replace(bad):
    """The Rust `convert_lossy` mirror == errors='replace', unit for unit."""
    for prefix in [b"", b"xy", "héllo ".encode("utf-8")]:
        for suffix in [b"", b" tail", "🙂".encode("utf-8")]:
            data = prefix + bad + suffix
            res = transcode_lossy(data)
            assert res["utf16"] == _cpython_lossy_utf16(data), data
            # None of the constructed inputs contain a literal U+FFFD.
            assert res["replacements"] == res["utf16"].count(REPLACEMENT), data
            rec = classify_utf8_error(data)
            assert res["first_error"] == rec, data


def test_transcode_lossy_clean_input():
    for text in ["", "ascii", "héllo wörld", "漢字テスト", "🙂🚀"]:
        res = transcode_lossy(text.encode("utf-8"))
        assert res["replacements"] == 0
        assert res["first_error"] is None
        assert res["utf16"] == _cpython_lossy_utf16(text.encode("utf-8"))


def test_transcode_lossy_random_corruption_seeds():
    """Seeded fuzz (no hypothesis dependency): random byte corruption of
    mixed-script text must match CPython's replacement decode exactly —
    the same differential the Rust suite runs engine by engine."""
    base = bytearray(("mixed é漢字🙂 ελληνικά русский text " * 8).encode("utf-8"))
    for seed in range(400):
        rng = random.Random(seed)
        data = bytearray(base)
        for _ in range(rng.randrange(1, 30)):
            data[rng.randrange(len(data))] = rng.randrange(256)
        res = transcode_lossy(bytes(data))
        assert res["utf16"] == _cpython_lossy_utf16(bytes(data)), seed
        try:
            bytes(data).decode("utf-8")
            assert res["first_error"] is None, seed
        except UnicodeDecodeError as e:
            assert res["first_error"]["position"] == e.start, seed


def test_error_records_for_rejected_rows():
    data = b"good ascii then bad: \xed\xa0\x80 tail"
    blocks, lengths = ref.blocks_from_utf8(data)
    blocks, lengths = ref.pad_batch(blocks, lengths, BLOCK_ROWS)
    records = error_records(blocks, lengths)
    assert len(records) == 1
    assert records[0]["kind"] == "surrogate"
    assert records[0]["position"] == 21
    assert records[0]["row"] == 0
