"""Failure-record classification: the Rust `TranscodeError` mirror.

Standalone from test_kernel.py so it runs without `hypothesis`; only the
`error_records` test needs the (jax) validation kernel.
"""

import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.validate import (
    ERROR_KINDS,
    classify_utf8_error,
    error_records,
)
from compile.kernels.utf8_to_utf16 import BLOCK_ROWS

BAD_SEQUENCES = [
    b"\x80",
    b"\xc0\x80",
    b"\xc1\xbf",
    b"\xc2",
    b"\xe0\x80\x80",
    b"\xe0\x9f\xbf",
    b"\xed\xa0\x80",
    b"\xf0\x80\x80\x80",
    b"\xf4\x90\x80\x80",
    b"\xf5\x80\x80\x80",
    b"\xff",
    b"abc\x80def",
    b"\xc2a",
    b"\xe1\x80\xc0\x80",
]


@pytest.mark.parametrize("bad", BAD_SEQUENCES, ids=range(len(BAD_SEQUENCES)))
def test_classifier_position_matches_cpython(bad):
    """The mirrored classifier reports CPython's UnicodeDecodeError.start."""
    for prefix in [b"", b"xy", "héllo ".encode("utf-8")]:
        data = prefix + bad
        rec = classify_utf8_error(data)
        try:
            data.decode("utf-8")
        except UnicodeDecodeError as e:
            assert rec is not None, data
            assert rec["position"] == e.start, data
            assert rec["kind"] in ERROR_KINDS, rec
        else:
            assert rec is None, data


def test_classifier_kinds_match_rust_convention():
    cases = {
        b"\xff": "header_bits",
        b"\x80": "too_long",
        b"\xc2": "too_short",
        b"\xc0\x80": "overlong",
        b"\xe0\x9f\xbf": "overlong",
        b"\xed\xa0\x80": "surrogate",
        b"\xf4\x90\x80\x80": "too_large",
        b"\xf5\x80\x80\x80": "too_large",
    }
    for data, kind in cases.items():
        assert classify_utf8_error(data)["kind"] == kind, data


def test_classifier_accepts_valid_text():
    for text in ["", "ascii", "héllo wörld", "漢字テスト", "🙂🚀"]:
        assert classify_utf8_error(text.encode("utf-8")) is None, text


def test_error_records_for_rejected_rows():
    data = b"good ascii then bad: \xed\xa0\x80 tail"
    blocks, lengths = ref.blocks_from_utf8(data)
    blocks, lengths = ref.pad_batch(blocks, lengths, BLOCK_ROWS)
    records = error_records(blocks, lengths)
    assert len(records) == 1
    assert records[0]["kind"] == "surrogate"
    assert records[0]["position"] == 21
    assert records[0]["row"] == 0
