"""L2: the batch transcoding graphs that get AOT-compiled for the Rust
runtime.

Two jitted entry points, each a composition of L1 kernels:

* ``utf8_to_utf16_graph``  — validate + transcode a batch of 64-byte
  UTF-8 blocks; returns (words, counts, valid).
* ``utf16_to_utf8_graph``  — transcode + validate a batch of UTF-16
  blocks; returns (bytes, counts, valid).

Both are lowered once by ``python/compile/aot.py`` to HLO text with a
fixed batch size; the Rust coordinator pads request batches to that
size.  Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels import (
    utf16_to_utf8_blocks,
    utf8_to_utf16_blocks,
    validate_utf8_blocks,
)

# Fixed AOT batch size (rows of 64 input units each). 64 rows x 64 bytes
# = 4 KiB of payload per executable invocation.
AOT_BATCH = 64


def utf8_to_utf16_graph(blocks, lengths):
    """Validate and transcode UTF-8 blocks in one fused graph.

    Args:
      blocks: (B, 64) int32 UTF-8 bytes, zero-padded, char-aligned rows.
      lengths: (B,) int32.

    Returns:
      (words (B, 64) int32, counts (B,) int32, valid (B,) bool).
      Rows that fail validation report count 0 and valid False.
    """
    valid = validate_utf8_blocks(blocks, lengths)
    words, counts = utf8_to_utf16_blocks(blocks, lengths)
    counts = jnp.where(valid, counts, 0)
    # int32 validity: the Rust runtime's Literal bridge has no bool lane.
    return words, counts, valid.astype(jnp.int32)


def utf16_to_utf8_graph(blocks, lengths):
    """Transcode UTF-16 blocks; validity comes from the same kernel."""
    out, counts, valid = utf16_to_utf8_blocks(blocks, lengths)
    counts = jnp.where(valid, counts, 0)
    return out, counts, valid.astype(jnp.int32)


def lower_utf8_to_utf16(batch: int = AOT_BATCH):
    spec_blocks = jax.ShapeDtypeStruct((batch, 64), jnp.int32)
    spec_lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return jax.jit(utf8_to_utf16_graph).lower(spec_blocks, spec_lens)


def lower_utf16_to_utf8(batch: int = AOT_BATCH):
    spec_blocks = jax.ShapeDtypeStruct((batch, 64), jnp.int32)
    spec_lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return jax.jit(utf16_to_utf8_graph).lower(spec_blocks, spec_lens)
