"""UTF-16 -> UTF-8 block transcoding kernel (the paper's Algorithm 4
dataflow, reformulated branch-free for a TPU-style target).

Block contract: each row is up to 64 UTF-16 code units (zero-padded),
surrogate pairs never straddle rows (the chunker splits on character
boundaries).

Per row the kernel emits up to 192 UTF-8 bytes (worst case: 64 BMP
3-byte characters) plus the byte count and a validity flag (lone
surrogates are the only way UTF-16 can be invalid -- paper section 3).
The expansion step mirrors Algorithm 4's 32-bit-lane cast; compaction is
the same one-hot matmul scatter as the UTF-8 -> UTF-16 kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
OUT_WIDTH = 192  # 64 units x up to 3 bytes each


def _shift_left(x, fill=0):
    return jnp.concatenate(
        [x[:, 1:], jnp.full((x.shape[0], 1), fill, x.dtype)], axis=1
    )


def _shift_right(x, fill=0):
    return jnp.concatenate(
        [jnp.full((x.shape[0], 1), fill, x.dtype), x[:, :-1]], axis=1
    )


def _transcode_tile(x, n):
    """(rows, 64) int32 UTF-16 units -> (bytes (rows, 192), counts, valid)."""
    rows, width = x.shape
    pos = jnp.arange(width, dtype=jnp.int32)[None, :]
    in_range = pos < n[:, None]
    w = jnp.where(in_range, x, 0)

    is_hi = (w >> 10) == 0x36  # 0xD800..0xDBFF
    is_lo = (w >> 10) == 0x37  # 0xDC00..0xDFFF
    next_w = _shift_left(w)
    next_is_lo = _shift_left(is_lo.astype(jnp.int32)) == 1
    prev_is_hi = _shift_right(is_hi.astype(jnp.int32)) == 1

    # Validation (Algorithm 4 case 4 is the only case needing it).
    bad = (is_hi & ~next_is_lo) | (is_lo & ~prev_is_hi)
    valid = jnp.sum((bad & in_range).astype(jnp.int32), axis=1) == 0

    # A unit starts a character unless it is the low half of a pair.
    is_start = in_range & ~(is_lo & prev_is_hi)
    cp = jnp.where(
        is_hi, 0x10000 + ((w - 0xD800) << 10) + (next_w - 0xDC00), w
    )

    # Byte length per starting unit (1-4).
    blen = jnp.where(
        cp < 0x80, 1, jnp.where(cp < 0x800, 2, jnp.where(cp < 0x10000, 3, 4))
    )
    blen = jnp.where(is_start, blen, 0)

    # The four candidate bytes per character (Algorithm 4's expansion,
    # all classes at once).
    b_of = [
        # leading byte by length
        jnp.where(
            blen == 1,
            cp,
            jnp.where(
                blen == 2,
                0xC0 | (cp >> 6),
                jnp.where(blen == 3, 0xE0 | (cp >> 12), 0xF0 | (cp >> 18)),
            ),
        ),
        jnp.where(
            blen == 2,
            0x80 | (cp & 0x3F),
            jnp.where(
                blen == 3, 0x80 | ((cp >> 6) & 0x3F), 0x80 | ((cp >> 12) & 0x3F)
            ),
        ),
        jnp.where(blen == 3, 0x80 | (cp & 0x3F), 0x80 | ((cp >> 6) & 0x3F)),
        0x80 | (cp & 0x3F),
    ]

    # Compaction: exclusive prefix sum of byte widths, one-hot scatter.
    out_pos = jnp.cumsum(blen, axis=1) - blen
    counts = jnp.sum(blen, axis=1)
    slot = jnp.arange(OUT_WIDTH, dtype=jnp.int32)[None, None, :]
    out = jnp.zeros((rows, OUT_WIDTH), dtype=jnp.int32)
    for j in range(4):
        pj = jnp.where(blen > j, out_pos + j, OUT_WIDTH)[:, :, None]
        onehot = (pj == slot).astype(jnp.int32)
        out = out + jnp.einsum("rk,rkj->rj", b_of[j], onehot)
    return out, counts, valid


def _kernel(x_ref, n_ref, bytes_ref, counts_ref, valid_ref):
    out, counts, valid = _transcode_tile(x_ref[...], n_ref[...])
    bytes_ref[...] = out
    counts_ref[...] = counts
    valid_ref[...] = valid


@functools.partial(jax.jit, static_argnames=())
def utf16_to_utf8_blocks(blocks, lengths):
    """Transcode a batch of UTF-16 blocks (64 units) to UTF-8 bytes.

    Args:
      blocks: (B, 64) int32 UTF-16 code units, zero-padded.
      lengths: (B,) int32 valid unit count per row.

    Returns:
      (bytes, counts, valid): (B, 192) int32 UTF-8 byte values, (B,)
      int32 byte counts, and (B,) bool validity flags.
    """
    batch, width = blocks.shape
    assert width == 64
    assert batch % BLOCK_ROWS == 0
    grid = (batch // BLOCK_ROWS,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, OUT_WIDTH), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, OUT_WIDTH), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.bool_),
        ],
        interpret=True,
    )(blocks, lengths)
