"""Keiser-Lemire UTF-8 validation kernel (paper section 4; reference [3]).

The x64/NEON original classifies adjacent byte pairs through three
16-entry ``pshufb`` tables and OR-reduces an error vector.  On the
TPU-style target the three table lookups become 16-way broadcast-compare
selects over nibbles (see ``_lookup16`` for why not a gather); the
``prev1/2/3`` lagged registers become shifted copies of the row (each
row is an independent 64-byte block starting at a character boundary, so
the carried-in context is zero == ASCII).

Zero padding doubles as the end-of-input incompleteness check: a
truncated multi-byte sequence at ``length`` is followed by a 0x00 byte,
which triggers TOO_SHORT exactly like the scalar validator's final
`prev_incomplete` test -- provided rows are zero-padded, which the
chunker guarantees (length < 64 or the row ends on a boundary).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import numpy as np

BLOCK_ROWS = 8

# Error-class bits (names from the original publication).
TOO_SHORT = 1 << 0
TOO_LONG = 1 << 1
OVERLONG_3 = 1 << 2
TOO_LARGE = 1 << 3
SURROGATE = 1 << 4
OVERLONG_2 = 1 << 5
TOO_LARGE_1000 = 1 << 6
OVERLONG_4 = 1 << 6
TWO_CONTS = 1 << 7
CARRY = TOO_SHORT | TOO_LONG | TWO_CONTS

BYTE_1_HIGH = (
    [TOO_LONG] * 8
    + [TWO_CONTS] * 4
    + [
        TOO_SHORT | OVERLONG_2,
        TOO_SHORT,
        TOO_SHORT | OVERLONG_3 | SURROGATE,
        TOO_SHORT | TOO_LARGE | TOO_LARGE_1000 | OVERLONG_4,
    ]
)

BYTE_1_LOW = (
    [
        CARRY | OVERLONG_3 | OVERLONG_2 | OVERLONG_4,
        CARRY | OVERLONG_2,
        CARRY,
        CARRY,
        CARRY | TOO_LARGE,
    ]
    + [CARRY | TOO_LARGE | TOO_LARGE_1000] * 8
    + [
        CARRY | TOO_LARGE | TOO_LARGE_1000 | SURROGATE,
        CARRY | TOO_LARGE | TOO_LARGE_1000,
        CARRY | TOO_LARGE | TOO_LARGE_1000,
    ]
)

BYTE_2_HIGH = (
    [TOO_SHORT] * 8
    + [
        TOO_LONG | OVERLONG_2 | TWO_CONTS | OVERLONG_3 | TOO_LARGE_1000 | OVERLONG_4,
        TOO_LONG | OVERLONG_2 | TWO_CONTS | OVERLONG_3 | TOO_LARGE,
        TOO_LONG | OVERLONG_2 | TWO_CONTS | SURROGATE | TOO_LARGE,
        TOO_LONG | OVERLONG_2 | TWO_CONTS | SURROGATE | TOO_LARGE,
    ]
    + [TOO_SHORT] * 4
)


def _lookup16(table, idx):
    """Branch-free 16-entry table lookup as broadcast-compare + select.

    The natural formulation is a gather (``jnp.take``), but the
    xla_extension 0.5.1 HLO-text path the Rust runtime relies on
    miscompiles 1-D-table gathers (it yields the indices); a 16-way
    compare/select chain is numerically identical, lowers to pure
    vector ops, and is in fact how a TPU VPU would broadcast a nibble
    classification.  ``table`` is a Python list of int constants.
    """
    out = jnp.zeros_like(idx)
    for k, v in enumerate(table):
        out = out + jnp.where(idx == k, np.int32(v), np.int32(0))
    return out


def _shift_right(x, k):
    """prev<k>: value k positions earlier in the row, zero-filled."""
    return jnp.pad(x, ((0, 0), (k, 0)))[:, : x.shape[1]]


def _validate_tile(x, n):
    """Validate a (rows, 64) tile; returns (rows,) bool `is_valid`."""
    width = x.shape[1]
    pos = jnp.arange(width, dtype=jnp.int32)[None, :]
    # Mask padding to zero (ASCII) so it cannot fabricate errors beyond
    # the truncation check described in the module docstring.
    x = jnp.where(pos < n[:, None], x, 0)

    prev1 = _shift_right(x, 1)
    sc = (
        _lookup16(BYTE_1_HIGH, prev1 >> 4)
        & _lookup16(BYTE_1_LOW, prev1 & 0x0F)
        & _lookup16(BYTE_2_HIGH, x >> 4)
    )
    prev2 = _shift_right(x, 2)
    prev3 = _shift_right(x, 3)
    # must-be-continuation: a 3-byte lead two back or a 4-byte lead three
    # back forces bit 7; XOR against the special-case classes exactly as
    # the SIMD original does (saturating-sub replaced by compares).
    must32_80 = jnp.where((prev2 >= 0xE0) | (prev3 >= 0xF0), 0x80, 0)
    err = must32_80 ^ sc
    return jnp.sum(err, axis=1) == 0


def _kernel(x_ref, n_ref, valid_ref):
    valid_ref[...] = _validate_tile(x_ref[...], n_ref[...])


@functools.partial(jax.jit, static_argnames=())
def validate_utf8_blocks(blocks, lengths):
    """Validate a batch of zero-padded 64-byte UTF-8 blocks.

    Args:
      blocks: (B, 64) int32 byte values.
      lengths: (B,) int32 valid byte count per row.

    Returns:
      (B,) bool: True where the row is valid UTF-8.
    """
    batch, width = blocks.shape
    assert width == 64
    assert batch % BLOCK_ROWS == 0
    grid = (batch // BLOCK_ROWS,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.bool_),
        interpret=True,
    )(blocks, lengths)


# ---------------------------------------------------------------------------
# Failure records mirroring the Rust `transcode::TranscodeError` API.
#
# The Rust side reports `(kind, position)` for the first invalid sequence
# (kinds below; positions are `str::Utf8Error::valid_up_to`-compatible).
# The Pallas kernel above only returns a per-row validity bit, so — like
# the Rust SIMD engines — the position/kind recovery is a scalar re-scan
# of the failing row. Emitting the same snake_case kind strings keeps
# Python and Rust harness failure records directly comparable.

#: Mirror of Rust ``transcode::ErrorKind::as_str`` values.
ERROR_KINDS = (
    "header_bits",  # byte with >= 5 header bits (0xF8..0xFF)
    "too_short",    # truncated sequence / missing continuation
    "too_long",     # continuation byte where a lead was expected
    "overlong",     # overlong encoding (incl. 0xC0/0xC1 leads)
    "surrogate",    # UTF-8-encoded surrogate code point
    "too_large",    # code point above U+10FFFF (incl. 0xF5..0xF7 leads)
    "output_buffer",
    "other",
)


def _decode_one(data, p):
    """Strict scalar decode of one character at ``data[p:]``.

    Returns ``(length, cp, None)`` on success or ``(None, None, kind)``
    on error — the same classification as Rust
    ``scalar::decode_utf8_char``.
    """
    b0 = data[p]
    if b0 < 0x80:
        return 1, b0, None
    if b0 < 0xC0:
        return None, None, "too_long"
    if b0 < 0xC2:
        return None, None, "overlong"
    if 0xF5 <= b0 < 0xF8:
        return None, None, "too_large"
    if b0 >= 0xF8:
        return None, None, "header_bits"
    n = 2 if b0 < 0xE0 else 3 if b0 < 0xF0 else 4
    cp = b0 & (0x7F >> n)
    for i in range(1, n):
        if p + i >= len(data) or (data[p + i] & 0xC0) != 0x80:
            return None, None, "too_short"
        cp = (cp << 6) | (data[p + i] & 0x3F)
    if n == 3:
        if cp < 0x800:
            return None, None, "overlong"
        if 0xD800 <= cp <= 0xDFFF:
            return None, None, "surrogate"
    elif n == 4:
        if cp < 0x10000:
            return None, None, "overlong"
        if cp > 0x10FFFF:
            return None, None, "too_large"
    return n, cp, None


def classify_utf8_error(data):
    """First UTF-8 error in ``data`` as ``{"kind", "position"}``, or None.

    ``position`` equals CPython's ``UnicodeDecodeError.start`` (and Rust's
    ``TranscodeError.position``): the index of the first byte of the first
    invalid sequence.
    """
    data = bytes(data)
    p = 0
    while p < len(data):
        length, _cp, kind = _decode_one(data, p)
        if kind is not None:
            return {"kind": kind, "position": p}
        p += length
    return None


# ---------------------------------------------------------------------------
# Lossy transcoding mirror.

#: U+FFFD REPLACEMENT CHARACTER as a UTF-16 code unit.
REPLACEMENT = 0xFFFD


def _maximal_subpart_len(data, p):
    """Length of the maximal invalid subpart at ``data[p]``.

    Mirror of Rust ``scalar::utf8_maximal_subpart_len`` (the WHATWG
    "U+FFFD substitution of maximal subparts" policy CPython's
    ``errors='replace'`` also implements): one replacement covers the
    longest prefix of a well-formed sequence, or a single byte when the
    lead (or its first continuation) can start nothing.
    """
    b0 = data[p]
    if 0xC2 <= b0 <= 0xDF:
        lo, hi, n = 0x80, 0xBF, 2
    elif b0 == 0xE0:
        lo, hi, n = 0xA0, 0xBF, 3
    elif 0xE1 <= b0 <= 0xEC or 0xEE <= b0 <= 0xEF:
        lo, hi, n = 0x80, 0xBF, 3
    elif b0 == 0xED:
        lo, hi, n = 0x80, 0x9F, 3
    elif b0 == 0xF0:
        lo, hi, n = 0x90, 0xBF, 4
    elif 0xF1 <= b0 <= 0xF3:
        lo, hi, n = 0x80, 0xBF, 4
    elif b0 == 0xF4:
        lo, hi, n = 0x80, 0x8F, 4
    else:
        return 1
    if p + 1 >= len(data) or not (lo <= data[p + 1] <= hi):
        return 1
    i = 2
    while p + i < len(data) and i < n:
        if (data[p + i] & 0xC0) != 0x80:
            return i
        i += 1
    return min(i, len(data) - p)


def _encode_utf16(cp):
    if cp < 0x10000:
        return [cp]
    v = cp - 0x10000
    return [0xD800 + (v >> 10), 0xDC00 + (v & 0x3FF)]


def transcode_lossy(data):
    """Lossy UTF-8 → UTF-16: mirror of Rust ``Utf8ToUtf16::convert_lossy``.

    Replaces each maximal invalid subpart with U+FFFD (WHATWG policy,
    identical to ``bytes(data).decode('utf-8', errors='replace')`` and
    Rust's ``String::from_utf8_lossy``) and returns::

        {"utf16": [code units], "replacements": n,
         "first_error": {"kind", "position"} | None}

    matching the fields of the Rust ``LossyResult`` — so Python and Rust
    harness records for the dirty-input workload are directly
    comparable.
    """
    data = bytes(data)
    out = []
    replacements = 0
    first_error = None
    p = 0
    while p < len(data):
        length, cp, kind = _decode_one(data, p)
        if kind is None:
            out.extend(_encode_utf16(cp))
            p += length
        else:
            if first_error is None:
                first_error = {"kind": kind, "position": p}
            out.append(REPLACEMENT)
            replacements += 1
            p += _maximal_subpart_len(data, p)
    return {"utf16": out, "replacements": replacements, "first_error": first_error}


# ---------------------------------------------------------------------------
# Counting mirror (Rust `count` module).
#
# The Rust side sizes exact allocations with SIMD counting kernels:
# UTF-16 words from UTF-8 = #non-continuation bytes + #4-byte leads,
# code points = #non-continuation bytes, and UTF-8 bytes from UTF-16 via
# five range masks with a pair shift (`((high << 1) | carry) & low`).
# The numpy formulations below are the same mask algebra, whole-array
# instead of per-register, so Python and Rust compute identical numbers
# for identical (arbitrary, not necessarily valid) input.


def utf16_len_from_utf8(data):
    """UTF-16 words needed for ``data`` (UTF-8 bytes, possibly invalid).

    Mirror of Rust ``count::utf16_len_from_utf8``: one word per
    non-continuation byte, one extra per ``>= 0xF0`` lead. For valid
    input equals ``len(bytes(data).decode().encode('utf-16-le')) // 2``.
    """
    a = np.frombuffer(bytes(data), dtype=np.uint8)
    if a.size == 0:
        return 0
    non_cont = (a & 0xC0) != 0x80
    return int(non_cont.sum()) + int((a >= 0xF0).sum())


def count_utf8_code_points(data):
    """Code points in ``data`` (= non-continuation bytes; for valid
    input equals ``len(bytes(data).decode())``)."""
    a = np.frombuffer(bytes(data), dtype=np.uint8)
    if a.size == 0:
        return 0
    return int(((a & 0xC0) != 0x80).sum())


def utf8_len_from_utf16(words):
    """UTF-8 bytes needed for ``words`` (UTF-16 code units).

    Mirror of Rust ``count::utf8_len_from_utf16`` and its SIMD mask
    algebra: every word counts ``1 + (w >= 0x80) + (w >= 0x800)`` — 3
    for any surrogate, the width of both U+FFFD and raw WTF-8 — minus 2
    for each high surrogate immediately followed by a low one (the pair
    is one 4-byte character, not 3+3). Exact for valid input; an upper
    bound under the unpaired-surrogate-counts-3 convention otherwise.
    """
    w = np.asarray(list(words), dtype=np.uint32)
    if w.size == 0:
        return 0
    n = w.size + int((w >= 0x80).sum()) + int((w >= 0x800).sum())
    high = (w >= 0xD800) & (w < 0xDC00)
    low = (w >= 0xDC00) & (w < 0xE000)
    pairs = int((high[:-1] & low[1:]).sum())
    return n - 2 * pairs


def count_utf16_code_points(words):
    """Code points in ``words`` (words minus high surrogates — a pair's
    high word starts the code point its low word completes)."""
    w = np.asarray(list(words), dtype=np.uint32)
    if w.size == 0:
        return 0
    return w.size - int(((w >= 0xD800) & (w < 0xDC00)).sum())


def error_records(blocks, lengths):
    """Structured failure records for a validated batch.

    Runs ``validate_utf8_blocks`` and, for each rejected row, re-scans the
    row's bytes to a ``{"row", "kind", "position"}`` record (position is
    relative to the row start, as each row starts on a character boundary).
    """
    valid = np.asarray(validate_utf8_blocks(blocks, lengths))
    blocks = np.asarray(blocks)
    lengths = np.asarray(lengths)
    records = []
    for r in np.flatnonzero(~valid):
        row = bytes(int(v) & 0xFF for v in blocks[r, : int(lengths[r])])
        rec = classify_utf8_error(row)
        if rec is None:
            # The kernel treats a truncated sequence at the padded row end
            # as invalid; mirror Rust's defensive too_short-at-end.
            rec = {"kind": "too_short", "position": int(lengths[r])}
        rec["row"] = int(r)
        records.append(rec)
    return records
