"""L1: Pallas kernels for batch Unicode transcoding.

The paper's hot loop is a pshufb-against-precomputed-masks pipeline
(Figs. 2-4).  That idiom does not map onto a TPU: there is no byte-level
arbitrary shuffle against VMEM, and branching per 12-byte window defeats
the vector units.  The kernels here re-derive the paper's dataflow for a
TPU-style target (DESIGN.md section "Hardware adaptation"):

* the shuffle mask is *computed* instead of loaded: a prefix-sum over the
  lead-byte mask yields each character's byte indexes, and a gather
  (``take_along_axis``) replaces ``pshufb`` -- the paper itself notes the
  compute-the-mask alternative in section 4;
* the per-window branch on the bitset becomes a branch-free select over
  all four character lengths;
* the variable-length output compaction becomes a cumulative-sum of
  per-character output widths followed by a one-hot matrix product --
  scatter as matmul, which is the MXU-friendly formulation;
* the Keiser-Lemire validator's three 16-entry ``pshufb`` table lookups
  become three 16-entry ``take`` gathers over nibbles.

All kernels run under ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom calls); the BlockSpec tiling is still shaped for a
(rows x 64) VMEM-resident tile per grid step.
"""

from .utf8_to_utf16 import utf8_to_utf16_blocks
from .utf16_to_utf8 import utf16_to_utf8_blocks
from .validate import validate_utf8_blocks

__all__ = [
    "utf8_to_utf16_blocks",
    "utf16_to_utf8_blocks",
    "validate_utf8_blocks",
]
