"""Pure-Python/NumPy reference oracles for the kernels.

These use CPython's own codecs (the most battle-tested Unicode
implementation available) as ground truth, shaped into the same
block-batch layout the kernels consume.  Every kernel result is compared
against these in ``python/tests``.
"""

import numpy as np

BLOCK = 64
OUT_WIDTH = 192


def blocks_from_utf8(data: bytes, block: int = BLOCK):
    """Split UTF-8 bytes into character-aligned zero-padded blocks.

    Mirrors the Rust chunker: greedy blocks of up to ``block`` bytes,
    trimmed back to a character boundary.  Returns (blocks, lengths) as
    int32 arrays of shape (B, block) / (B,).
    """
    rows = []
    lens = []
    i = 0
    while i < len(data):
        end = min(i + block, len(data))
        # trim back to a boundary (first byte of next char is not a
        # continuation byte)
        while end < len(data) and end > i and (data[end] & 0xC0) == 0x80:
            end -= 1
        if end == i:  # pathological (invalid) input: give up on alignment
            end = min(i + block, len(data))
        chunk = data[i:end]
        row = np.zeros(block, dtype=np.int32)
        row[: len(chunk)] = np.frombuffer(chunk, dtype=np.uint8)
        rows.append(row)
        lens.append(len(chunk))
        i = end
    if not rows:
        rows = [np.zeros(block, dtype=np.int32)]
        lens = [0]
    return np.stack(rows), np.array(lens, dtype=np.int32)


def blocks_from_utf16(units, block: int = BLOCK):
    """Split UTF-16 code units into pair-aligned zero-padded blocks."""
    units = list(units)
    rows = []
    lens = []
    i = 0
    while i < len(units):
        end = min(i + block, len(units))
        # do not split a surrogate pair
        if end < len(units) and 0xD800 <= units[end - 1] < 0xDC00:
            end -= 1
        chunk = units[i:end]
        row = np.zeros(block, dtype=np.int32)
        row[: len(chunk)] = np.array(chunk, dtype=np.int32)
        rows.append(row)
        lens.append(len(chunk))
        i = end
    if not rows:
        rows = [np.zeros(block, dtype=np.int32)]
        lens = [0]
    return np.stack(rows), np.array(lens, dtype=np.int32)


def pad_batch(blocks, lengths, multiple):
    """Pad the batch dimension to a multiple (kernels tile by BLOCK_ROWS)."""
    b = blocks.shape[0]
    rem = (-b) % multiple
    if rem:
        blocks = np.concatenate([blocks, np.zeros((rem, blocks.shape[1]), blocks.dtype)])
        lengths = np.concatenate([lengths, np.zeros(rem, lengths.dtype)])
    return blocks, lengths


def utf8_to_utf16_ref(blocks, lengths):
    """Reference: per-row UTF-8 -> UTF-16LE via Python codecs."""
    batch, width = blocks.shape
    words = np.zeros((batch, width), dtype=np.int32)
    counts = np.zeros(batch, dtype=np.int32)
    for r in range(batch):
        raw = bytes(blocks[r, : lengths[r]].astype(np.uint8).tolist())
        units = np.frombuffer(
            raw.decode("utf-8").encode("utf-16-le"), dtype=np.uint16
        ).astype(np.int32)
        words[r, : len(units)] = units
        counts[r] = len(units)
    return words, counts


def validate_utf8_ref(blocks, lengths):
    """Reference: per-row UTF-8 validity via Python codecs."""
    batch = blocks.shape[0]
    ok = np.zeros(batch, dtype=bool)
    for r in range(batch):
        raw = bytes(blocks[r, : lengths[r]].astype(np.uint8).tolist())
        try:
            raw.decode("utf-8", errors="strict")
            ok[r] = True
        except UnicodeDecodeError:
            ok[r] = False
    return ok


def utf16_to_utf8_ref(blocks, lengths):
    """Reference: per-row UTF-16 -> UTF-8 via Python codecs."""
    batch, width = blocks.shape
    out = np.zeros((batch, OUT_WIDTH), dtype=np.int32)
    counts = np.zeros(batch, dtype=np.int32)
    valid = np.zeros(batch, dtype=bool)
    for r in range(batch):
        units = blocks[r, : lengths[r]].astype(np.uint16)
        raw = units.tobytes()
        try:
            enc = raw.decode("utf-16-le", errors="strict").encode("utf-8")
            arr = np.frombuffer(enc, dtype=np.uint8).astype(np.int32)
            out[r, : len(arr)] = arr
            counts[r] = len(arr)
            valid[r] = True
        except UnicodeDecodeError:
            valid[r] = False
    return out, counts, valid
