"""UTF-8 -> UTF-16 block transcoding kernel (the paper's Algorithm 2/3
dataflow, reformulated gather-first for a TPU-style target).

Block contract (enforced by the Rust chunker in ``rust/src/coordinator``):

* each row is one 64-byte block of UTF-8, zero-padded after ``length``;
* rows start and end on character boundaries;
* rows contain valid UTF-8 (run the validation kernel first otherwise).

Outputs per row: 64 UTF-16 code units (int32, zero-padded) and the count
of units written.  A 64-byte block yields at most 64 units (all-ASCII)
and at least 16 (all 4-byte characters -> 32 units), so the output tile
is the same (rows, 64) shape as the input.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step: a (8, 64) int32 input tile plus the intermediate
# (8, 64, 64) one-hot is the VMEM budget driver; see DESIGN.md "Perf".
BLOCK_ROWS = 8


def _transcode_tile(x, n):
    """Transcode a (rows, 64) int32 byte tile; n is (rows,) lengths.

    Returns (words (rows, 64) int32, counts (rows,) int32).
    """
    rows, width = x.shape
    pos = jnp.arange(width, dtype=jnp.int32)[None, :]  # (1, 64)
    in_range = pos < n[:, None]

    # --- character segmentation (Algorithm 3 lines 4-9, computed) ---
    is_cont = ((x >> 6) == 0b10) & in_range
    is_lead = (~is_cont) & in_range
    # Start index of character k, in order: sort the lead positions.
    # (The SIMD original derives the same information from the
    # end-of-character bitset + table; here it is a sort/prefix-sum.)
    starts = jnp.sort(jnp.where(is_lead, pos, width), axis=1)  # (rows, 64)
    nchars = jnp.sum(is_lead.astype(jnp.int32), axis=1)  # (rows,)

    # --- gather each character's bytes (the computed "shuffle") ---
    def gather(offset):
        idx = jnp.clip(starts + offset, 0, width - 1)
        return jnp.take_along_axis(x, idx, axis=1)

    b0, b1, b2, b3 = gather(0), gather(1), gather(2), gather(3)

    # --- branch-free compose (Figs. 2-4 bit math, all lengths at once) ---
    cp1 = b0
    cp2 = ((b0 & 0x1F) << 6) | (b1 & 0x3F)
    cp3 = ((b0 & 0x0F) << 12) | ((b1 & 0x3F) << 6) | (b2 & 0x3F)
    cp4 = (
        ((b0 & 0x07) << 18)
        | ((b1 & 0x3F) << 12)
        | ((b2 & 0x3F) << 6)
        | (b3 & 0x3F)
    )
    cp = jnp.where(
        b0 < 0x80,
        cp1,
        jnp.where(b0 < 0xE0, cp2, jnp.where(b0 < 0xF0, cp3, cp4)),
    )

    # --- UTF-16 synthesis incl. surrogate pairs (Fig. 4 final step) ---
    char_valid = jnp.arange(width, dtype=jnp.int32)[None, :] < nchars[:, None]
    is_supp = (cp >= 0x10000) & char_valid
    v = cp - 0x10000
    w0 = jnp.where(is_supp, 0xD800 + (v >> 10), cp)
    w1 = jnp.where(is_supp, 0xDC00 + (v & 0x3FF), 0)
    units = jnp.where(char_valid, 1 + is_supp.astype(jnp.int32), 0)

    # --- compaction: exclusive prefix sum + scatter-as-matmul ---
    out_pos = jnp.cumsum(units, axis=1) - units  # (rows, 64)
    counts = jnp.sum(units, axis=1)
    # One-hot scatter (64 chars -> 64 output slots); padded/overflow
    # positions target slot index `width` and fall off the one-hot.
    slot = jnp.arange(width, dtype=jnp.int32)[None, None, :]  # (1, 1, 64)
    p0 = jnp.where(units > 0, out_pos, width)[:, :, None]
    p1 = jnp.where(units > 1, out_pos + 1, width)[:, :, None]
    onehot0 = (p0 == slot).astype(jnp.int32)  # (rows, 64, 64)
    onehot1 = (p1 == slot).astype(jnp.int32)
    words = jnp.einsum("rk,rkj->rj", w0, onehot0) + jnp.einsum(
        "rk,rkj->rj", w1, onehot1
    )
    return words, counts


def _kernel(x_ref, n_ref, words_ref, counts_ref):
    words, counts = _transcode_tile(x_ref[...], n_ref[...])
    words_ref[...] = words
    counts_ref[...] = counts


@functools.partial(jax.jit, static_argnames=())
def utf8_to_utf16_blocks(blocks, lengths):
    """Transcode a batch of 64-byte UTF-8 blocks to UTF-16 code units.

    Args:
      blocks: (B, 64) int32 byte values in [0, 256), zero-padded.
      lengths: (B,) int32 valid byte count per row.

    Returns:
      (words, counts): (B, 64) int32 UTF-16 code units and (B,) int32
      unit counts.
    """
    batch, width = blocks.shape
    assert width == 64, "the paper's block size"
    assert batch % BLOCK_ROWS == 0, f"batch must be a multiple of {BLOCK_ROWS}"
    grid = (batch // BLOCK_ROWS,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, width), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(blocks, lengths)
