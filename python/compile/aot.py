"""AOT: lower the L2 graphs to HLO text for the Rust PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's bundled XLA (xla_extension 0.5.1) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md and gen_hlo.py.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None, help="legacy single-file alias")
    parser.add_argument("--batch", type=int, default=model.AOT_BATCH)
    args = parser.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    jobs = {
        f"utf8_to_utf16_b{args.batch}.hlo.txt": model.lower_utf8_to_utf16(args.batch),
        f"utf16_to_utf8_b{args.batch}.hlo.txt": model.lower_utf16_to_utf8(args.batch),
    }
    for name, lowered in jobs.items():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")
    # Marker consumed by the Makefile's up-to-date check.
    if args.out:
        with open(args.out, "w") as f:
            f.write("see *.hlo.txt artifacts in this directory\n")


if __name__ == "__main__":
    main()
