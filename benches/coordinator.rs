//! `cargo bench --bench coordinator` — end-to-end service benchmark:
//! the L3 coordinator serving a mixed transcoding workload (both
//! directions, all wikipedia-Mars languages) across worker counts.
//!
//! This is the system-level complement to the per-engine tables: it
//! shows the coordinator is not the bottleneck (DESIGN.md §Perf L3
//! target) by comparing aggregate service throughput against the raw
//! single-thread engine speed.

use simdutf_rs::coordinator::{EngineChoice, Request, ServiceConfig, TranscodeService};
use simdutf_rs::prelude::*;
use std::time::Instant;

fn run(workers: usize, requests: usize, corpora: &[Corpus]) -> (f64, f64) {
    let service = TranscodeService::start(ServiceConfig {
        workers,
        queue_depth: 1024,
        engine: EngineChoice::Simd { validate: true },
        ..Default::default()
    })
    .expect("service");
    let started = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        let corpus = &corpora[i % corpora.len()];
        let req = if i % 2 == 0 {
            Request::utf8(i as u64, corpus.utf8_prefix(16 * 1024).to_vec())
        } else {
            Request::utf16(i as u64, corpus.utf16_prefix(8 * 1024).to_vec())
        };
        pending.push(service.submit(req).expect("admitted"));
    }
    for rx in pending {
        assert!(rx.recv().unwrap().ok());
    }
    let elapsed = started.elapsed();
    let snap = service.stats();
    let gcs = snap.chars as f64 / elapsed.as_secs_f64() / 1e9;
    let mean_latency_us = snap.mean_latency.as_secs_f64() * 1e6;
    service.shutdown();
    (gcs, mean_latency_us)
}

fn main() {
    let corpora = simdutf_rs::corpus::generate_collection(Collection::WikipediaMars);
    let requests = 2000;
    println!("coordinator end-to-end: {requests} mixed requests (16 KiB utf8 / 8 Kwords utf16)");
    println!("{:>8} {:>14} {:>16}", "workers", "Gchars/s", "mean latency µs");
    for workers in [1, 2, 4, 8] {
        let (gcs, lat) = run(workers, requests, &corpora);
        println!("{workers:>8} {gcs:>14.3} {lat:>16.1}");
    }
}
