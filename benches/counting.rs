//! `cargo bench --bench counting` — the counting subsystem sweep.
//!
//! Two questions, per the exact-size allocation pitch (ISSUE 4 /
//! *Unicode at Gigabytes per Second*):
//!
//! 1. How fast are the counting kernels themselves? Every registry
//!    kernel set (`scalar` reference, `simd128`, `simd256`, `simd512`,
//!    `best`) ×
//!    every lipsum corpus, all four kernels, input MB/s — the `scalar`
//!    row is the baseline the SIMD speedup is read against.
//! 2. What does the `*_to_vec` convenience path cost under each
//!    allocation strategy? `zeroed` (the seed's `vec![0; worst_case]`)
//!    vs `uninit` (`convert_to_vec`) vs `exact`
//!    (`convert_to_vec_exact`), allocation deliberately inside the
//!    timed region (the harness module docs call this exception out).
//!
//! Budget per cell via `SIMDUTF_BENCH_BUDGET_MS` (default 200 ms).

use simdutf_rs::corpus::{generate_collection, Collection};
use simdutf_rs::engine::Registry;
use simdutf_rs::harness::{
    bench_alloc_utf16_mbps, bench_alloc_utf8_mbps, bench_count_utf16_mbps,
    bench_count_utf8_mbps, AllocStrategy,
};

fn main() {
    let corpora = generate_collection(Collection::Lipsum);
    let r = Registry::global();

    let corpus_header = |width: usize| {
        print!("  {:>w$}", "", w = width);
        for corpus in &corpora {
            print!("  {:>10}", corpus.name());
        }
        println!();
    };

    println!(
        "Counting kernels (input MB/s), lipsum; best = {}",
        simdutf_rs::simd::best_key()
    );
    // Each row carries its accessor so the label can never drift from
    // the kernel actually measured.
    type Pick8 = fn(&simdutf_rs::count::CountKernels) -> fn(&[u8]) -> usize;
    type Pick16 = fn(&simdutf_rs::count::CountKernels) -> fn(&[u16]) -> usize;
    let utf8_kernels: [(&str, Pick8); 2] = [
        ("utf16_len_from_utf8", |k| k.utf16_len_from_utf8),
        ("count_utf8_code_points", |k| k.count_utf8_code_points),
    ];
    let utf16_kernels: [(&str, Pick16); 2] = [
        ("utf8_len_from_utf16", |k| k.utf8_len_from_utf16),
        ("count_utf16_code_points", |k| k.count_utf16_code_points),
    ];
    for (name, pick) in utf8_kernels {
        println!("{name}:");
        for k in r.count_entries() {
            print!("  {:>8}", k.key);
            for corpus in &corpora {
                let v = bench_count_utf8_mbps(pick(k), &corpus.utf8);
                print!("  {:>10}", format!("{v:.0}"));
            }
            println!();
        }
        corpus_header(8);
        println!();
    }
    for (name, pick) in utf16_kernels {
        println!("{name}:");
        for k in r.count_entries() {
            print!("  {:>8}", k.key);
            for corpus in &corpora {
                let v = bench_count_utf16_mbps(pick(k), &corpus.utf16);
                print!("  {:>10}", format!("{v:.0}"));
            }
            println!();
        }
        corpus_header(8);
        println!();
    }

    // Alloc-strategy head-to-head on the best engine (the perf claim of
    // this subsystem: exact/uninit must beat the seed's zeroed path at
    // least on the ASCII-heavy and mixed corpora).
    let best8 = r.get_utf8("best").expect("registry always has best");
    let best16 = r.get_utf16("best").expect("registry always has best");
    println!("to_vec allocation strategies, UTF-8→UTF-16, `best` engine (input MB/s)");
    for strategy in AllocStrategy::ALL {
        print!("  {:>8}", strategy.key());
        for corpus in &corpora {
            let v = bench_alloc_utf8_mbps(best8, corpus, strategy);
            print!("  {:>10}", format!("{v:.0}"));
        }
        println!();
    }
    corpus_header(8);
    println!();

    println!("to_vec allocation strategies, UTF-16→UTF-8, `best` engine (input MB/s)");
    for strategy in AllocStrategy::ALL {
        print!("  {:>8}", strategy.key());
        for corpus in &corpora {
            let v = bench_alloc_utf16_mbps(best16, corpus, strategy);
            print!("  {:>10}", format!("{v:.0}"));
        }
        println!();
    }
    corpus_header(8);
}
