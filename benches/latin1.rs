//! `cargo bench --bench latin1` — the Latin-1 subsystem sweep.
//!
//! Latin-1 is the crate's pure expand/compress workload (ISSUE 5 /
//! *Unicode at Gigabytes per Second*): every kernel set (`scalar`
//! reference, `simd128`, `simd256`, `simd512`, `best`) across the four
//! `latin1 ⇄ utf8/utf16` directions, on two corpora:
//!
//! * `mixed` — [`Corpus::latin1`]: word-like ASCII with ~15% of
//!   characters in `U+00C0..=U+00FF`, so the interleave/compress cores
//!   do real work;
//! * `ascii` — the paper's pure-ASCII Latin lipsum profile, where the
//!   64-byte block fast path should dominate and all kernels converge.
//!
//! The `scalar` row is the baseline the SIMD speedup is read against.
//! Ends with the exact-allocation head-to-head (`latin1_to_utf8_vec`
//! vs a worst-case zeroed buffer), the one set of cells that times
//! allocation + conversion together on purpose.
//!
//! Budget per cell via `SIMDUTF_BENCH_BUDGET_MS` (default 200 ms).

use simdutf_rs::corpus::{Collection, Corpus, Language};
use simdutf_rs::engine::Registry;
use simdutf_rs::harness::{
    bench_latin1_to_utf16_mbps, bench_latin1_to_utf8_mbps, bench_utf16_to_latin1_mbps,
    bench_utf8_to_latin1_mbps,
};

fn main() {
    let mixed = Corpus::latin1(Collection::Lipsum);
    let ascii = Corpus::generate(Language::Latin, Collection::Lipsum);
    let inputs: Vec<(&str, Vec<u8>, &Corpus)> = vec![
        ("mixed", mixed.latin1_bytes().expect("convertible by construction"), &mixed),
        ("ascii", ascii.latin1_bytes().expect("pure ASCII"), &ascii),
    ];
    let r = Registry::global();

    let header = || {
        print!("  {:>8}", "");
        for (label, _, _) in &inputs {
            print!("  {:>10}", label);
        }
        println!();
    };

    println!(
        "Latin-1 kernels (input MB/s), lipsum-sized corpora; best = {}",
        simdutf_rs::simd::best_key()
    );

    println!("latin1_to_utf8 (expand):");
    for k in r.latin1_entries() {
        print!("  {:>8}", k.key);
        for (_, latin1, _) in &inputs {
            let v = bench_latin1_to_utf8_mbps(k.latin1_to_utf8, latin1);
            print!("  {:>10}", format!("{v:.0}"));
        }
        println!();
    }
    header();
    println!();

    println!("utf8_to_latin1 (compress):");
    for k in r.latin1_entries() {
        print!("  {:>8}", k.key);
        for (_, _, corpus) in &inputs {
            let v = bench_utf8_to_latin1_mbps(k.utf8_to_latin1, &corpus.utf8);
            print!("  {:>10}", format!("{v:.0}"));
        }
        println!();
    }
    header();
    println!();

    println!("latin1_to_utf16 (zero-extend):");
    for k in r.latin1_entries() {
        print!("  {:>8}", k.key);
        for (_, latin1, _) in &inputs {
            let v = bench_latin1_to_utf16_mbps(k.latin1_to_utf16, latin1);
            print!("  {:>10}", format!("{v:.0}"));
        }
        println!();
    }
    header();
    println!();

    println!("utf16_to_latin1 (narrow):");
    for k in r.latin1_entries() {
        print!("  {:>8}", k.key);
        for (_, _, corpus) in &inputs {
            let v = bench_utf16_to_latin1_mbps(k.utf16_to_latin1, &corpus.utf16);
            print!("  {:>10}", format!("{v:.0}"));
        }
        println!();
    }
    header();
    println!();

    // Allocation head-to-head: the exact-size uninit path vs the seed
    // idiom (zeroed worst case + truncate). Allocation deliberately
    // inside the timed region — that is the comparison.
    use simdutf_rs::transcode::latin1::{latin1_to_utf8_vec, utf8_capacity_for_latin1};
    use std::time::Instant;
    let budget_ms: u64 = std::env::var("SIMDUTF_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    println!("latin1_to_utf8 allocation strategies (input MB/s, alloc inside the timed region)");
    for (label, latin1, _) in &inputs {
        let time = |f: &dyn Fn() -> usize| {
            let deadline = Instant::now() + std::time::Duration::from_millis(budget_ms);
            let mut best = f64::INFINITY;
            loop {
                let t0 = Instant::now();
                std::hint::black_box(f());
                best = best.min(t0.elapsed().as_secs_f64());
                if Instant::now() >= deadline {
                    break;
                }
            }
            latin1.len() as f64 / best / 1e6
        };
        let zeroed = time(&|| {
            let mut dst = vec![0u8; utf8_capacity_for_latin1(latin1.len())];
            let n = simdutf_rs::transcode::latin1::latin1_to_utf8(latin1, &mut dst)
                .expect("total");
            dst.truncate(n);
            dst.len()
        });
        let exact = time(&|| latin1_to_utf8_vec(latin1).expect("total").len());
        println!("  {label:>8}  zeroed-worst-case {zeroed:>8.0}  exact-uninit {exact:>8.0}");
    }
}
