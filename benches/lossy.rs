//! `cargo bench --bench lossy` — the dirty-input workload.
//!
//! Sweeps **lossy** conversion (`convert_lossy`, WHATWG U+FFFD
//! replacement) over every validating registry engine, on the paper's
//! lipsum corpora both clean and under each corruption profile of
//! [`simdutf_rs::corpus::DIRT_PROFILES`]. Two claims are on display:
//!
//! * on **clean** input, lossy throughput equals strict throughput
//!   (the resume loop costs one `convert` call — the head-to-head
//!   table at the end makes the comparison explicit);
//! * on **dirty** input, throughput degrades smoothly with the
//!   corruption rate (each error pays a bounded scalar re-scan, not a
//!   restart).
//!
//! Budget per cell via `SIMDUTF_BENCH_BUDGET_MS` (default 200 ms).

use simdutf_rs::corpus::{generate_collection, Collection, DirtProfile, DIRT_PROFILES};
use simdutf_rs::engine::Registry;
use simdutf_rs::harness;

fn main() {
    let corpora = generate_collection(Collection::Lipsum);
    let r = Registry::global();

    // One pseudo-profile for the clean pass, then the real ones.
    let passes: Vec<(String, Option<DirtProfile>)> =
        std::iter::once(("clean".to_string(), None))
            .chain(DIRT_PROFILES.iter().map(|&p| (p.label.to_string(), Some(p))))
            .collect();

    for (label, profile) in &passes {
        println!("Lossy UTF-8→UTF-16 (input MB/s), lipsum, {label}");
        for entry in r.utf8_lossy_entries() {
            print!("  {:>10}", entry.key);
            for corpus in &corpora {
                let bytes = match profile {
                    None => corpus.utf8.clone(),
                    Some(p) => corpus.dirty_utf8(*p, 0xD1A7),
                };
                let v = harness::bench_utf8_engine_lossy_mbps(entry.engine.as_ref(), &bytes);
                print!("  {:>9}", format!("{v:.0}"));
            }
            println!();
        }
        print!("  {:>10}", "");
        for corpus in &corpora {
            print!("  {:>9}", corpus.name());
        }
        println!("\n");
    }

    for (label, profile) in &passes {
        println!("Lossy UTF-16→UTF-8 (input MB/s), lipsum, {label}");
        for entry in r.utf16_lossy_entries() {
            print!("  {:>10}", entry.key);
            for corpus in &corpora {
                let words = match profile {
                    None => corpus.utf16.clone(),
                    Some(p) => corpus.dirty_utf16(*p, 0xD1A7),
                };
                let v = harness::bench_utf16_engine_lossy_mbps(entry.engine.as_ref(), &words);
                print!("  {:>9}", format!("{v:.0}"));
            }
            println!();
        }
        print!("  {:>10}", "");
        for corpus in &corpora {
            print!("  {:>9}", corpus.name());
        }
        println!("\n");
    }

    // Head-to-head on valid input: the lossy wrapper must be free.
    println!("Valid-input overhead check, `best` engine (strict vs lossy MB/s)");
    let best = r.get_utf8("best").expect("registry always has best");
    for corpus in &corpora {
        let strict = harness::bench_utf8_engine_mbps(best, corpus);
        let l = harness::bench_utf8_engine_lossy_mbps(best, &corpus.utf8);
        if let Some(s) = strict {
            println!(
                "  {:>9}  strict {:>8}  lossy {:>8}  ratio {:.3}",
                corpus.name(),
                format!("{s:.0}"),
                format!("{l:.0}"),
                l / s
            );
        }
    }
}
