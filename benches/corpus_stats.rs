//! `cargo bench --bench corpus_stats` — regenerates Table 4 (dataset
//! statistics) and reports corpus generation + validation throughput
//! (the Keiser–Lemire validator is a dependency of the paper's
//! validating transcoders).

use simdutf_rs::harness::bench::{default_budget, measure};
use simdutf_rs::prelude::*;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let corpora = simdutf_rs::corpus::generate_collection(Collection::Lipsum);
    println!("generated {} lipsum corpora in {:?}\n", corpora.len(), t0.elapsed());

    println!(
        "{}",
        simdutf_rs::harness::run_section("table4", std::path::Path::new("artifacts")).unwrap()
    );

    // Validation-only throughput (GB/s) per dataset.
    println!("Keiser–Lemire validation throughput (GB/s, lipsum)");
    for corpus in &corpora {
        let r = measure(
            || {
                std::hint::black_box(validate_utf8(&corpus.utf8));
            },
            default_budget(),
            3,
        );
        println!(
            "  {:>10}  {:>6.2}",
            corpus.name(),
            corpus.utf8.len() as f64 / r.min.as_secs_f64() / 1e9
        );
    }
}
