//! `cargo bench --bench utf8_to_utf16` — regenerates the paper's UTF-8 →
//! UTF-16 evaluation: Table 5 (non-validating, lipsum), Table 6
//! (validating, lipsum), Figure 5 (bar subset), Table 7 (validating,
//! wikipedia-Mars) and Table 8 (path counters, Arabic lipsum).
//!
//! Methodology follows §6.1: repeated in-memory conversions, minimum
//! timing, gigacharacters per second. Budget per cell is controlled by
//! `SIMDUTF_BENCH_BUDGET_MS` (default 200 ms).

fn main() {
    for section in ["table5", "table6", "fig5", "table7", "table8"] {
        let out = simdutf_rs::harness::run_section(section, std::path::Path::new("artifacts"))
            .expect("known section");
        println!("{out}");
    }
}
