//! `cargo bench --bench utf8_to_utf16` — regenerates the paper's UTF-8 →
//! UTF-16 evaluation: Table 5 (non-validating, lipsum), Table 6
//! (validating, lipsum), Figure 5 (bar subset), Table 7 (validating,
//! wikipedia-Mars) and Table 8 (path counters, Arabic lipsum) — then a
//! full engine × corpus sweep over **every** `engine::Registry` entry,
//! including the width-explicit `simd128`/`simd256`/`simd512` backends
//! and the runtime-dispatched `best` alias.
//!
//! Methodology follows §6.1: repeated in-memory conversions, minimum
//! timing, gigacharacters per second. Budget per cell is controlled by
//! `SIMDUTF_BENCH_BUDGET_MS` (default 200 ms).

use simdutf_rs::corpus::{generate_collection, Collection};
use simdutf_rs::engine::Registry;
use simdutf_rs::harness;

fn main() {
    for section in ["table5", "table6", "fig5", "table7", "table8"] {
        let out = harness::run_section(section, std::path::Path::new("artifacts"))
            .expect("known section");
        println!("{out}");
    }

    // Registry-wide sweep (the engine list comes from the registry, not
    // a hand-written list — width keys included).
    println!(
        "All registered UTF-8→UTF-16 engines (input MB/s, lipsum; best = {})",
        simdutf_rs::simd::best_key()
    );
    let corpora = generate_collection(Collection::Lipsum);
    for entry in Registry::global().utf8_entries() {
        print!("  {:>14}", entry.key);
        for corpus in &corpora {
            match harness::bench_utf8_engine_mbps(entry.engine.as_ref(), corpus) {
                Some(v) => print!("  {:>10}", format!("{v:.0}")),
                None => print!("  {:>10}", "n/a"),
            }
        }
        println!();
    }
    print!("  {:>14}", "");
    for corpus in &corpora {
        print!("  {:>10}", corpus.name());
    }
    println!();
}
