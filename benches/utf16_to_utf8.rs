//! `cargo bench --bench utf16_to_utf8` — regenerates the paper's UTF-16
//! → UTF-8 evaluation: Table 9 (lipsum), Figure 6 (bar subset), Table 10
//! (wikipedia-Mars), plus Figure 7 (speed vs input length, both
//! directions).

fn main() {
    for section in ["table9", "fig6", "table10", "fig7"] {
        let out = simdutf_rs::harness::run_section(section, std::path::Path::new("artifacts"))
            .expect("known section");
        println!("{out}");
    }
}
