//! `cargo bench --bench utf16_to_utf8` — regenerates the paper's UTF-16
//! → UTF-8 evaluation: Table 9 (lipsum), Figure 6 (bar subset), Table 10
//! (wikipedia-Mars), plus Figure 7 (speed vs input length, both
//! directions) — then a sweep over every `engine::Registry` UTF-16→UTF-8
//! entry, including `simd128`/`simd256`/`simd512`/`best`.

use simdutf_rs::corpus::{generate_collection, Collection};
use simdutf_rs::engine::Registry;
use simdutf_rs::harness;

fn main() {
    for section in ["table9", "fig6", "table10", "fig7"] {
        let out = harness::run_section(section, std::path::Path::new("artifacts"))
            .expect("known section");
        println!("{out}");
    }

    println!(
        "All registered UTF-16→UTF-8 engines (input MB/s, lipsum; best = {})",
        simdutf_rs::simd::best_key()
    );
    let corpora = generate_collection(Collection::Lipsum);
    for entry in Registry::global().utf16_entries() {
        print!("  {:>14}", entry.key);
        for corpus in &corpora {
            let v = harness::bench_utf16_engine_mbps(entry.engine.as_ref(), corpus);
            print!("  {:>10}", format!("{v:.0}"));
        }
        println!();
    }
    print!("  {:>14}", "");
    for corpus in &corpora {
        print!("  {:>10}", corpus.name());
    }
    println!();
}
