//! `cargo bench --bench parallel` — the parallel-pipeline thread sweep.
//!
//! Every `Registry::parallel_entries` cell — the validating
//! width-explicit engines (`simd128`, `simd256`, `simd512`, `best`) ×
//! the fixed
//! {1, 2, 4, 8} thread ladder — running `par_convert_to_vec` end to end
//! (boundary-safe split, count-first planning, allocation, scoped
//! workers) on one tiled corpus, both strict directions plus the
//! `latin1 → utf8` leg. The `@1` rows are the baseline the scaling is
//! read against; `@1` vs the one-shot `convert_to_vec_exact` row
//! isolates the pipeline's fixed overhead (split + per-chunk counting).
//!
//! Corpus size: 1 GiB by default ([`Corpus::tiled`] over the first
//! lipsum profile), overridable with `SIMDUTF_PAR_BENCH_BYTES` — CI
//! smoke runs pass a few MiB. Budget per cell via
//! `SIMDUTF_BENCH_BUDGET_MS` (default 200 ms).

use simdutf_rs::corpus::{generate_collection, Collection, Corpus};
use simdutf_rs::engine::Registry;
use simdutf_rs::prelude::*;
use std::time::{Duration, Instant};

fn budget() -> Duration {
    let ms: u64 = std::env::var("SIMDUTF_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    Duration::from_millis(ms)
}

/// Min-of-iterations MB/s for `f` over `input_bytes` of input.
fn mbps(input_bytes: usize, budget: Duration, f: &dyn Fn() -> usize) -> f64 {
    std::hint::black_box(f()); // warmup
    let deadline = Instant::now() + budget;
    let mut best = f64::INFINITY;
    loop {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
        if Instant::now() >= deadline {
            break;
        }
    }
    input_bytes as f64 / best / 1e6
}

fn main() {
    let target: usize = std::env::var("SIMDUTF_PAR_BENCH_BYTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 30);
    let base = &generate_collection(Collection::Lipsum)[0];
    let corpus = Corpus::tiled(base, target);
    let latin1: Vec<u8> = corpus.utf8.iter().map(|&b| b & 0x7F).collect();
    let budget = budget();
    let r = Registry::global();

    println!(
        "parallel pipeline sweep: {} tiled to {} bytes, budget {:?}/cell, best = {}",
        corpus.name(),
        corpus.utf8.len(),
        budget,
        simdutf_rs::simd::best_key()
    );

    println!("utf8_to_utf16 strict (input MB/s):");
    for e in r.parallel_entries() {
        let engine = r.get_utf8(e.engine).expect("parallel entries resolve");
        let opts = ParallelOptions::with_threads(e.threads);
        let v = mbps(corpus.utf8.len(), budget, &|| {
            engine
                .par_convert_to_vec(&corpus.utf8, opts.clone())
                .expect("tiled corpus is valid")
                .len()
        });
        println!("  {:>12}  {v:>8.0}", e.key);
    }
    // One-shot reference: what `@1` pays for the pipeline machinery.
    let best8 = r.get_utf8("best").expect("registry has best");
    let v = mbps(corpus.utf8.len(), budget, &|| {
        best8.convert_to_vec_exact(&corpus.utf8).expect("valid").len()
    });
    println!("  {:>12}  {v:>8.0}", "best oneshot");

    println!("utf16_to_utf8 strict (input MB/s):");
    for e in r.parallel_entries() {
        let engine = r.get_utf16(e.engine).expect("parallel entries resolve");
        let opts = ParallelOptions::with_threads(e.threads);
        let v = mbps(corpus.utf16.len() * 2, budget, &|| {
            engine
                .par_convert_to_vec(&corpus.utf16, opts.clone())
                .expect("tiled corpus is valid")
                .len()
        });
        println!("  {:>12}  {v:>8.0}", e.key);
    }
    let best16 = r.get_utf16("best").expect("registry has best");
    let v = mbps(corpus.utf16.len() * 2, budget, &|| {
        best16.convert_to_vec_exact(&corpus.utf16).expect("valid").len()
    });
    println!("  {:>12}  {v:>8.0}", "best oneshot");

    println!("latin1_to_utf8 (input MB/s, ASCII-masked corpus):");
    // By key, not index: entry order is not a contract (index 3 is
    // actually `simd512`).
    let kernels = *r
        .latin1_entries()
        .iter()
        .find(|k| k.key == "best")
        .expect("registry has a best Latin-1 set");
    for threads in [1usize, 2, 4, 8] {
        let opts = ParallelOptions::with_threads(threads);
        let v = mbps(latin1.len(), budget, &|| {
            par_latin1_to_utf8_vec(kernels, &latin1, opts.clone()).expect("latin1 is total").len()
        });
        println!("  {:>12}  {v:>8.0}", format!("best@{threads}"));
    }
}
